//! Batched command streams: one ring doorbell per plan-group.
//!
//! The reverse-offload path used to pay one 64-byte ring message and one
//! proxy service *per device-initiated op* (§III-D) — which dominates
//! latency exactly in the small-message regime the copy-engine route is
//! supposed to win. A [`CmdStream`] amortizes that: executors append
//! [`TransferPlan`]-shaped entries as [`BatchDescriptor`]s, payloads are
//! staged through the PE's symmetric-heap [`StagingSlab`] (turning
//! raw-pointer transfers into heap-offset transfers that run on real
//! `DeviceAddr` command lists), and the stream flushes as a single
//! `RingOp::Batch` message pointing at a descriptor block in the slab.
//!
//! Flush triggers:
//! * **capacity** — pending depth reaches `max_batch_depth` (fire-and-
//!   forget flush; the batch completion is tracked so `quiet` can drain);
//! * **blocking completion** — a blocking op appends its own entry and
//!   flushes synchronously (which also pushes out any pending NBI
//!   entries, preserving per-PE FIFO order);
//! * **non-batchable op** — anything that still ships its own ring
//!   message (fetching AMOs, quiet itself) flushes the pending stream
//!   first so the ring stays FIFO-consistent. Put-signal used to be on
//!   this list; with `chain.enable` it submits as a *triggered chain*
//!   instead (ISSUE 10) and no longer forces a flush of its own;
//! * **triggered chain** — [`PeCtx::stream_post_chain`] ships a whole
//!   stage-stamped dependency chain as exactly ONE `Batch` doorbell
//!   (pending NBI entries are pushed out first so the chain's batch
//!   contains only the chain; the proxy dispatches it stage by stage).
//!
//! Slab reclamation is batch-granular: every payload stage and every
//! descriptor block is one slab claim; when a batch's completion arrives
//! the claims are released and the arena rewinds once idle.
//!
//! **Reliability layer** (`retry.enable`, ISSUE 9): every Put-shaped
//! entry is stamped with a payload checksum at append; the proxy verifies
//! it before dispatch and answers a *NACK* status carrying a per-entry
//! failure mask instead of panicking. Because slab claims are held until
//! completion-ack, the NACKed entries' payload bytes are still in the
//! slab, pristine — the retire loop charges a modeled exponential backoff
//! (`retry.backoff_base_ns × retry.backoff_mult^(n−1)`), re-encodes just
//! the failed descriptors with a bumped attempt counter, and re-posts
//! them as a fresh batch, up to `retry.max_attempts` times before
//! surfacing a structured [`DegradedError`]. Independently,
//! `xfer.op_timeout_ms` bounds every completion wait on the p2p path
//! (blocking flushes, quiet/fence drains, slab-reclaim retires) — both
//! knobs default off, keeping the pre-reliability path bit-for-bit.
//!
//! [`TransferPlan`]: super::plan::TransferPlan
//! [`BatchDescriptor`]: crate::ringbuf::BatchDescriptor
//! [`StagingSlab`]: crate::sos::heap::StagingSlab
//! [`DegradedError`]: crate::sim::fault::DegradedError

use std::cell::RefCell;
use std::collections::VecDeque;

use crate::coordinator::metrics::Metrics;
use crate::ishmem::config::RetryConfig;
use crate::ishmem::PeCtx;
use crate::ringbuf::{payload_checksum, BatchDescriptor, CompletionToken, Message, RingOp, DESC_SIZE};
use crate::sim::fault::{bounded_poll, DegradedError, DegradedKind};

use super::exec::{PROXY_ERR_UNREGISTERED, PROXY_NACK, PROXY_OK};

/// Entries a batch NACK status can address: the completion value packs
/// the status code in the low byte and a per-entry failure bitmask above
/// it. `retry.enable` therefore requires `max_batch_depth ≤ 48`
/// (validated in `ishmem::config`).
pub const NACK_MASK_BITS: usize = 48;

/// Compose a NACK completion status from a non-empty failure mask.
pub(crate) fn encode_nack(mask: u64) -> u64 {
    debug_assert!(mask != 0 && mask < 1 << NACK_MASK_BITS);
    PROXY_NACK | (mask << 8)
}

/// Decode a completion status as a NACK mask, if it is one.
pub(crate) fn decode_nack(status: u64) -> Option<u64> {
    (status & 0xFF == PROXY_NACK).then(|| status >> 8)
}

/// Modeled backoff charged to the initiator clock before replay attempt
/// `attempt` (1-based): `base × mult^(attempt−1)`. Repeated
/// multiplication, not `powf`, so the figure benches can predict the
/// metric total bit-exactly.
pub fn retry_backoff_ns(cfg: &RetryConfig, attempt: u32) -> u64 {
    let mut ns = cfg.backoff_base_ns as f64;
    for _ in 1..attempt {
        ns *= cfg.backoff_mult;
    }
    ns as u64
}

/// Pending (not yet flushed) batch entry: the wire descriptor plus the
/// number of staging-slab claims its payload holds.
#[derive(Debug)]
struct PendingEntry {
    desc: BatchDescriptor,
    slab_claims: usize,
}

/// A posted-but-unretired batch: its completion token, the slab claims
/// (entries + descriptor blocks) to release when it completes, the
/// descriptors it carried (the replay loop re-posts NACKed ones — their
/// payloads are still pinned in the slab by the unreleased claims), and
/// which replay attempt this posting is (0 = first transmission).
#[derive(Debug)]
struct InflightBatch {
    token: CompletionToken,
    slab_claims: usize,
    descs: Vec<BatchDescriptor>,
    attempt: u32,
}

/// Per-(initiator, work-group) command stream. `PeCtx` is `!Sync` and all
/// work-group variants funnel through their leader's `PeCtx`, so plain
/// interior mutability suffices.
#[derive(Debug)]
pub struct CmdStream {
    max_depth: usize,
    /// Size-adaptive batch depth: a descriptor whose payload is at or
    /// above this size flushes its plan-group immediately after the
    /// append, so a big chunk never waits behind a filling batch of tiny
    /// entries (deep batches for small descriptors, shallow auto-flush
    /// for large ones).
    large_flush_bytes: usize,
    pending: RefCell<Vec<PendingEntry>>,
    inflight: RefCell<VecDeque<InflightBatch>>,
}

impl CmdStream {
    pub fn new(max_depth: usize) -> Self {
        assert!(max_depth >= 1, "batch depth must be at least 1");
        CmdStream {
            max_depth,
            large_flush_bytes: usize::MAX,
            pending: RefCell::new(Vec::new()),
            inflight: RefCell::new(VecDeque::new()),
        }
    }

    /// Set the size-adaptive flush boundary (`stream.large_flush_bytes`).
    pub fn with_large_flush_bytes(mut self, bytes: usize) -> Self {
        self.large_flush_bytes = bytes.max(1);
        self
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn large_flush_bytes(&self) -> usize {
        self.large_flush_bytes
    }

    pub fn pending_len(&self) -> usize {
        self.pending.borrow().len()
    }

    pub fn inflight_len(&self) -> usize {
        self.inflight.borrow().len()
    }
}

/// Slab headroom preserved above every payload claim so a descriptor
/// block for a full plan-group can always be written at flush time —
/// the single source for `stream_slab_alloc`/`stream_slab_try_alloc`
/// and for `IshmemConfig::chunk_max_bytes()`'s double-buffer cap.
pub(crate) fn slab_headroom_bytes(max_depth: usize) -> usize {
    (max_depth + 1) * DESC_SIZE + 192
}

impl PeCtx {
    // ------------------------------------------------------ slab staging --

    /// Claim `len` slab bytes for a payload or a get-result, retiring
    /// finished (and, if needed, pending) batches to make room. `None`
    /// means the payload cannot fit the slab at all — the caller falls
    /// back to the raw-pointer path.
    pub(crate) fn stream_slab_alloc(&self, len: usize) -> Option<usize> {
        let headroom = slab_headroom_bytes(self.stream.max_depth());
        let need = len.checked_add(64 + headroom)?;
        if need > self.slab.capacity() {
            // Can never fit, even empty: take the raw-pointer fallback
            // without stalling on in-flight batches or force-flushing the
            // pending plan-group (the fallback's own ring post flushes
            // pending for FIFO).
            return None;
        }
        if self.slab.available() < need {
            self.stream_drain_inflight();
            if self.slab.available() < need && self.stream.pending_len() > 0 {
                self.stream_flush_ff();
                self.stream_drain_inflight();
            }
        }
        if self.slab.available() < need {
            return None;
        }
        self.slab.try_alloc(len)
    }

    /// Claim `len` slab bytes *without* force-flushing the pending
    /// plan-group: retires finished batches only. Used by the chunked-get
    /// window builder, whose own pending descriptors must stay pending
    /// (flushing them fire-and-forget would release their slab claims
    /// before the single-threaded PE copies the results out). `None`
    /// simply ends the current window.
    pub(crate) fn stream_slab_try_alloc(&self, len: usize) -> Option<usize> {
        let headroom = slab_headroom_bytes(self.stream.max_depth());
        let need = len.checked_add(64 + headroom)?;
        if need > self.slab.capacity() {
            return None;
        }
        if self.slab.available() < need {
            self.stream_drain_inflight();
        }
        if self.slab.available() < need {
            return None;
        }
        self.slab.try_alloc(len)
    }

    /// Stage a private (raw-pointer) payload into the slab: after this
    /// copy the transfer is heap-offset shaped and can execute on real
    /// `DeviceAddr` command lists. Charges the HBM-local staging copy.
    pub(crate) fn stream_stage_payload(&self, src: &[u8]) -> Option<usize> {
        let off = self.stream_stage_payload_uncharged(src)?;
        self.clock.advance(self.rt.cost.staging_copy_ns(src.len()));
        Some(off)
    }

    /// Stage without the modeled charge — the striped chunk pipeline
    /// overlaps staging of chunk *k+1* with engine execution of chunk
    /// *k*, so chunked executors charge one aggregate pipeline time
    /// instead of serial per-chunk copies.
    pub(crate) fn stream_stage_payload_uncharged(&self, src: &[u8]) -> Option<usize> {
        let off = self.stream_slab_alloc(src.len())?;
        self.rt.heaps.heap(self.pe()).write(off, src);
        Some(off)
    }

    // ----------------------------------------------------------- append ---

    /// Append a descriptor to the stream (`slab_claims` = claims its
    /// payload holds; 0 for entries whose source already lives in the
    /// user heap). Charges the descriptor write; flushes fire-and-forget
    /// when the plan-group reaches capacity *or* the entry's payload is
    /// large (`stream.large_flush_bytes` — the size-adaptive depth: tiny
    /// descriptors batch deep, a big chunk ships at once).
    pub(crate) fn stream_append(&self, desc: BatchDescriptor, slab_claims: usize) {
        let desc = self.stream_stamp_checksum(desc);
        self.clock.advance(self.rt.cost.staging_copy_ns(DESC_SIZE));
        let large = desc.len as usize >= self.stream.large_flush_bytes();
        let depth = {
            let mut pending = self.stream.pending.borrow_mut();
            pending.push(PendingEntry { desc, slab_claims });
            pending.len()
        };
        if depth >= self.stream.max_depth() || large {
            self.stream_flush_ff();
        }
    }

    /// Stamp a payload checksum on a Put-shaped entry (reliability layer).
    /// The source is always an initiator-heap offset at this point (slab
    /// stage or user heap — raw pointers never reach the batch path), so
    /// the bytes the proxy will read are exactly the bytes summed here.
    /// Gets are excluded (their payload doesn't exist yet); inline puts
    /// and AMOs carry their payload in the descriptor itself. A disabled
    /// `retry.enable` stamps nothing — descriptors stay bit-for-bit.
    fn stream_stamp_checksum(&self, desc: BatchDescriptor) -> BatchDescriptor {
        if !self.rt.config.retry.enable || desc.op != RingOp::Put as u8 || desc.len == 0 {
            return desc;
        }
        let mut buf = vec![0u8; desc.len as usize];
        self.rt.heaps.heap(self.pe()).read(desc.src_off as usize, &mut buf);
        desc.with_checksum(payload_checksum(&buf))
    }

    // ----------------------------------------------------------- flushes --

    /// Write the pending descriptors into a slab block and post the one
    /// `Batch` doorbell. Returns the completion token, the batch's total
    /// slab claims, and its descriptors (kept for NACK replay); `None`
    /// when nothing is pending.
    fn stream_post_batch(&self) -> Option<(CompletionToken, usize, Vec<BatchDescriptor>)> {
        let entries: Vec<PendingEntry> = {
            let mut pending = self.stream.pending.borrow_mut();
            if pending.is_empty() {
                return None;
            }
            pending.drain(..).collect()
        };
        let n = entries.len();
        let block_len = n * DESC_SIZE;
        let block_off = match self.slab.try_alloc(block_len) {
            Some(off) => off,
            None => {
                // Slab pinned by in-flight batches: retire them (FIFO —
                // always safe) and retry; the headroom invariant makes
                // this allocation infallible afterwards.
                self.stream_drain_inflight();
                self.slab
                    .try_alloc(block_len)
                    .expect("staging slab cannot hold a descriptor block")
            }
        };
        let descs: Vec<BatchDescriptor> = entries.iter().map(|e| e.desc).collect();
        self.rt
            .heaps
            .heap(self.pe())
            .write(block_off, &BatchDescriptor::encode_block(&descs));
        let claims: usize = entries.iter().map(|e| e.slab_claims).sum::<usize>() + 1;

        let pool = self.completions().clone();
        let token = pool.alloc();
        let mut m = Message::nop();
        m.op = RingOp::Batch as u8;
        m.src_pe = self.pe() as u32;
        m.dst_off = block_off as u64;
        m.len = n as u64;
        m.completion = token.index;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.ring().send(m);
        Some((token, claims, descs))
    }

    /// Fire-and-forget flush: one doorbell for the pending plan-group;
    /// completion is tracked in-flight so `quiet` (or a later capacity
    /// squeeze) retires it. Charges one ring post for the whole group.
    pub(crate) fn stream_flush_ff(&self) {
        if let Some((token, slab_claims, descs)) = self.stream_post_batch() {
            self.stream
                .inflight
                .borrow_mut()
                .push_back(InflightBatch { token, slab_claims, descs, attempt: 0 });
            self.clock.advance(self.rt.cost.ring_post_ns());
        }
    }

    /// A batch completion carries one status for the whole plan-group;
    /// decode the failure like `check_proxy_status` does for single ops.
    /// (NBI entries surface here at the next flush/quiet/fence — later
    /// than the offending op, the price of fire-and-forget batching.)
    fn check_batch_status(&self, status: u64) {
        match status {
            PROXY_OK => {}
            PROXY_ERR_UNREGISTERED => panic!(
                "batched submission failed: a target heap in the plan-group is not \
                 FI_HMEM-registered (strict mode)"
            ),
            other => panic!("batched submission failed: proxy status {other}"),
        }
    }

    /// Wait on one proxy completion under the `xfer.op_timeout_ms`
    /// deadline. Timeout 0 (the default) is the pre-deadline unbounded
    /// spin, bit-for-bit. On expiry the op counts `xfer_op_timeouts` and
    /// unwinds with a structured [`DegradedError`] (`panic_any`, so
    /// harnesses can downcast it). The completion slot is deliberately
    /// *leaked* on timeout: the proxy may still complete it later, and
    /// freeing a pending slot would let a stale completion corrupt its
    /// next user.
    pub(crate) fn proxy_wait_completion(
        &self,
        token: CompletionToken,
        what: &'static str,
        attempts: u32,
    ) -> u64 {
        let timeout_ms = self.rt.config.xfer.op_timeout_ms;
        if timeout_ms == 0 {
            return self.completions().wait(token);
        }
        let pool = self.completions();
        match bounded_poll(
            timeout_ms,
            || pool.try_wait(&token),
            |ms| DegradedError::p2p(DegradedKind::OpTimeout, what, "proxy", 0, attempts, self.pe(), ms),
        ) {
            Ok(_) => pool.finish(token),
            Err(e) => {
                Metrics::add(&self.rt.metrics.xfer_op_timeouts, 1);
                std::panic::panic_any(e);
            }
        }
    }

    /// Retire one posted batch: wait (deadline-bounded), and on a clean
    /// status release its slab claims. A NACK status instead drives the
    /// replay loop — charge the modeled backoff, re-encode exactly the
    /// failed entries with a bumped attempt counter (their payloads are
    /// still pinned in the slab), post them as a fresh batch, and wait
    /// again — until the status is clean or `retry.max_attempts` replays
    /// are spent, which unwinds with `DegradedError::RetryExhausted`.
    fn stream_retire_batch(&self, mut batch: InflightBatch, what: &'static str) {
        let mut backoff_total_ns = 0u64;
        loop {
            let status = self.proxy_wait_completion(batch.token, what, batch.attempt);
            let mask = match decode_nack(status) {
                None => {
                    self.check_batch_status(status);
                    if self.rt.config.retry.enable {
                        self.track.note_attempt(batch.attempt);
                    }
                    for _ in 0..batch.slab_claims {
                        self.slab.release();
                    }
                    return;
                }
                Some(mask) => mask,
            };
            let rcfg = self.rt.config.retry;
            assert!(
                rcfg.enable,
                "proxy NACKed a batch while retry.enable is off — the checksum \
                 machinery should be dormant (status {status:#x})"
            );
            let failed: Vec<BatchDescriptor> = batch
                .descs
                .iter()
                .enumerate()
                .filter(|(i, _)| (mask >> i) & 1 == 1)
                .map(|(_, d)| *d)
                .collect();
            assert!(!failed.is_empty(), "NACK status carried an empty entry mask");
            Metrics::add(&self.rt.metrics.retry_nacks, 1);
            let attempt = batch.attempt + 1;
            if attempt > rcfg.max_attempts {
                Metrics::add(&self.rt.metrics.retry_exhausted, 1);
                let d = failed[0];
                let route = if self.rt.topo().node_of(d.pe as usize) == self.node() {
                    "engine"
                } else {
                    "rail"
                };
                std::panic::panic_any(DegradedError::p2p(
                    DegradedKind::RetryExhausted,
                    what,
                    route,
                    d.engine_hint(),
                    batch.attempt,
                    self.pe(),
                    backoff_total_ns / 1_000_000,
                ));
            }
            let backoff = retry_backoff_ns(&rcfg, attempt);
            backoff_total_ns += backoff;
            self.clock.advance(backoff as f64);
            Metrics::add(&self.rt.metrics.retry_backoff_ns_total, backoff);
            Metrics::add(&self.rt.metrics.retry_replays, failed.len() as u64);
            self.track.note_replayed(failed.len() as u64);
            // Idempotent replay: the original payload claims were never
            // released, so every failed entry's src_off still points at
            // its pristine staged bytes. Only a fresh descriptor block is
            // allocated (one more claim, released with the rest on the
            // clean completion).
            let descs: Vec<BatchDescriptor> =
                failed.iter().map(|d| d.with_attempt(attempt as u16)).collect();
            let block_len = descs.len() * DESC_SIZE;
            let block_off = self
                .slab
                .try_alloc(block_len)
                .expect("staging slab cannot hold a replay descriptor block");
            self.rt
                .heaps
                .heap(self.pe())
                .write(block_off, &BatchDescriptor::encode_block(&descs));
            let pool = self.completions().clone();
            let token = pool.alloc();
            let mut m = Message::nop();
            m.op = RingOp::Batch as u8;
            m.src_pe = self.pe() as u32;
            m.dst_off = block_off as u64;
            m.len = descs.len() as u64;
            m.completion = token.index;
            Metrics::add(&self.rt.metrics.ring_messages, 1);
            self.ring().send(m);
            self.clock.advance(self.rt.cost.ring_post_ns());
            batch = InflightBatch {
                token,
                slab_claims: batch.slab_claims + 1,
                descs,
                attempt,
            };
        }
    }

    /// Blocking flush: retire everything in flight, post the pending
    /// plan-group, and wait for its completion. The ring is FIFO per
    /// node, so on return every earlier entry of this PE is serviced.
    /// Callers charge the modeled route cost themselves.
    pub(crate) fn stream_flush_blocking(&self) {
        self.stream_drain_inflight();
        if let Some((token, slab_claims, descs)) = self.stream_post_batch() {
            self.stream_retire_batch(
                InflightBatch { token, slab_claims, descs, attempt: 0 },
                "batch-flush",
            );
        }
    }

    /// Submit a triggered chain (ISSUE 10): stage-stamped descriptors
    /// that ship as exactly ONE `Batch` doorbell; the proxy dispatches
    /// them stage by stage, each stage gated on its predecessors'
    /// completion (and on any `WaitSignal` gate entries). Unrelated
    /// pending NBI entries are pushed out first with their own doorbell
    /// so the chain's batch contains only the chain — entry indices and
    /// NACK masks then line up with chain stages. Blocking: the chain
    /// retires before return, so a later same-PE op can never overtake
    /// a successor stage. Counts the chain depth histogram and the
    /// `depth − 1` doorbells fusion reclaimed vs sequential submission.
    pub(crate) fn stream_post_chain(&self, entries: Vec<(BatchDescriptor, usize)>) {
        debug_assert!(!entries.is_empty(), "empty chain submission");
        debug_assert!(
            entries.len() <= self.stream.max_depth(),
            "chain deeper than max_batch_depth"
        );
        self.stream_flush_ff();
        let depth = entries.len();
        {
            let mut pending = self.stream.pending.borrow_mut();
            for (desc, slab_claims) in entries {
                let desc = self.stream_stamp_checksum(desc);
                pending.push(PendingEntry { desc, slab_claims });
            }
        }
        self.clock.advance(self.rt.cost.staging_copy_ns(depth * DESC_SIZE));
        self.rt.metrics.add_chain(depth);
        Metrics::add(
            &self.rt.metrics.chain_fused_doorbells,
            depth.saturating_sub(1) as u64,
        );
        self.stream_flush_blocking();
    }

    /// Wait out all in-flight batches and release their slab claims.
    /// Returns how many batches were retired (no modeled charge here —
    /// `quiet` charges one ring round trip for the drain).
    pub(crate) fn stream_drain_inflight(&self) -> usize {
        let mut drained = 0;
        loop {
            let batch = match self.stream.inflight.borrow_mut().pop_front() {
                Some(b) => b,
                None => break,
            };
            self.stream_retire_batch(batch, "batch-drain");
            drained += 1;
        }
        drained
    }

    /// `quiet`/`fence` entry point: push out the pending plan-group and
    /// retire every batch in flight. Returns whether anything was
    /// outstanding (the caller charges the drain round trip if so).
    pub(crate) fn stream_quiet_drain(&self) -> bool {
        self.stream_flush_ff();
        self.stream_drain_inflight() > 0
    }

    /// Retire every outstanding batch *and* return this PE's reserved
    /// per-engine and per-rail backlog to the shared `CostModel` (each
    /// engine/rail slot releases exactly what striped NBI transfers
    /// reserved on it). The cleanup half of `quiet` (no modeled charges)
    /// — shared with launch exit so per-PE state can never leak into the
    /// machine across launches.
    pub(crate) fn drain_outstanding(&self) -> bool {
        let drained = self.stream_quiet_drain();
        let gpu = self.my_gpu();
        for (engine, bytes) in self.track.take_engine_bytes() {
            self.rt.cost.engine_release_on(gpu, engine, bytes);
        }
        let node = self.node();
        for (rail, bytes) in self.track.take_rail_bytes() {
            self.rt.cost.rail_release_on(node, rail, bytes);
        }
        self.track.take_chunks();
        self.track.take_chain_links();
        drained
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_state_starts_empty() {
        let s = CmdStream::new(16);
        assert_eq!(s.max_depth(), 16);
        assert_eq!(s.pending_len(), 0);
        assert_eq!(s.inflight_len(), 0);
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn zero_depth_rejected() {
        CmdStream::new(0);
    }

    #[test]
    fn nack_status_codec_roundtrips() {
        for mask in [1u64, 0b1010, 1 << 47, (1 << 48) - 1] {
            let status = encode_nack(mask);
            assert_eq!(decode_nack(status), Some(mask), "mask {mask:#x}");
            assert_ne!(status & 0xFF, PROXY_OK, "NACK must not read as OK");
            assert_ne!(status & 0xFF, PROXY_ERR_UNREGISTERED);
        }
        assert_eq!(decode_nack(PROXY_OK), None);
        assert_eq!(decode_nack(PROXY_ERR_UNREGISTERED), None);
    }

    #[test]
    fn backoff_schedule_is_exponential_and_deterministic() {
        let cfg = RetryConfig {
            backoff_base_ns: 1000,
            backoff_mult: 2.0,
            ..RetryConfig::default()
        };
        assert_eq!(retry_backoff_ns(&cfg, 1), 1000);
        assert_eq!(retry_backoff_ns(&cfg, 2), 2000);
        assert_eq!(retry_backoff_ns(&cfg, 4), 8000);
        // mult 1.0 = constant backoff.
        let flat = RetryConfig { backoff_mult: 1.0, ..cfg };
        assert_eq!(retry_backoff_ns(&flat, 1), retry_backoff_ns(&flat, 7));
    }

    #[test]
    fn large_flush_boundary_defaults_off_and_clamps() {
        let s = CmdStream::new(8);
        assert_eq!(s.large_flush_bytes(), usize::MAX);
        let s = CmdStream::new(8).with_large_flush_bytes(256 << 10);
        assert_eq!(s.large_flush_bytes(), 256 << 10);
        // 0 would flush every append including empty AMOs; clamp to ≥1.
        let s = CmdStream::new(8).with_large_flush_bytes(0);
        assert_eq!(s.large_flush_bytes(), 1);
    }
}
