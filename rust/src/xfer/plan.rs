//! The planner: one `TransferPlan` per device-initiated operation.
//!
//! `XferEngine` is the single place that models candidate paths and picks
//! a route, for point-to-point RMA/signals (paper Fig 3–5) *and* for
//! collective fan-outs (Fig 6–7, where the decision also depends on the
//! PE count via the fan-out shape). Executors (`exec.rs`) then charge the
//! queue-aware actual costs and feed them back via [`XferEngine::record`]
//! so `CutoverMode::Adaptive` learns online.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;
use crate::ishmem::cutover::{CutoverConfig, CutoverMode, Path};
use crate::sim::cost::CollOp;
use crate::sim::params::ParamsSnapshot;
use crate::sim::topology::Locality;
use crate::sim::CostModel;
use crate::util::hash::{fast_hash, FastState};

use super::adaptive::{argmin_path, AdaptiveCell, AdaptiveTable, BucketKey};

/// What kind of operation a plan describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Contiguous put (blocking or NBI).
    Put,
    /// Contiguous get (blocking or NBI).
    Get,
    /// Put + signal-word update.
    PutSignal,
    /// Collective one-to-many push (broadcast/fcollect/collect lanes).
    Fanout,
}

/// The executor a plan is bound to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Organic load/store by the calling work-item(s) (§III-B).
    LoadStore,
    /// Reverse offload → host proxy → copy engines (§III-C).
    CopyEngine,
    /// Inter-node: reverse offload → host proxy → OFI/NIC (§III-D).
    Nic,
}

impl Route {
    /// The intra-node cutover path this route corresponds to (Nic has
    /// none: unreachable targets never had a path choice).
    pub fn as_path(self) -> Option<Path> {
        match self {
            Route::LoadStore => Some(Path::LoadStore),
            Route::CopyEngine => Some(Path::CopyEngine),
            Route::Nic => None,
        }
    }
}

/// A planned device-initiated transfer: everything the executor and the
/// completion tracker need, plus the modeled costs that justified the
/// choice (kept for adaptive feedback and reports).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferPlan {
    pub kind: OpKind,
    pub loc: Locality,
    pub bytes: usize,
    /// Cooperating work-items (1 for scalar-thread APIs).
    pub items: usize,
    /// Destination peers (1 for point-to-point, fan-out width for
    /// collectives — Fig 6's third cutover axis).
    pub peers: usize,
    pub route: Route,
    /// Modeled cost of the chosen route, ns (pure model — executors may
    /// charge a queue-aware refinement and `record` the difference).
    pub modeled_ns: f64,
    /// Modeled cost of the rejected intra-node path, ns (None on `Nic`:
    /// there was no alternative).
    pub alt_ns: Option<f64>,
    /// Chunk size of the striped engine pipeline (= `bytes` when the
    /// transfer ships as one unit). Chosen by the cost model's stripe
    /// planner under the staging-slab chunk cap.
    pub chunk_bytes: usize,
    /// Engines the chunks stripe across (1 = un-striped).
    pub stripe_width: usize,
    /// `ModelParams` version the estimates were priced under (closed-loop
    /// calibration): a plan stamped before a recalibration carries modeled
    /// costs from the old hardware model, and downstream consumers
    /// (reports, persisted tables) can tell.
    pub model_version: u64,
}

impl TransferPlan {
    /// Number of chunks this plan's executor slices the payload into.
    pub fn chunks(&self) -> usize {
        if self.chunk_bytes == 0 || self.chunk_bytes >= self.bytes {
            1
        } else {
            self.bytes.div_ceil(self.chunk_bytes)
        }
    }
    /// Bucket key for the adaptive table (fan-outs learn in their own
    /// cells — their observations cover a whole one-to-many push; remote
    /// point-to-point cells carry the rail-width dimension so multi-rail
    /// observations never alias single-rail ones).
    pub fn bucket(&self) -> BucketKey {
        match self.kind {
            OpKind::Fanout => BucketKey::fanout(self.loc, self.bytes, self.items, self.peers),
            _ if self.loc == Locality::Remote => {
                BucketKey::remote(self.bytes, self.items, self.stripe_width)
            }
            _ => BucketKey::p2p(self.loc, self.bytes, self.items),
        }
    }
}

/// Shape of a collective fan-out, pre-digested by the caller (who owns the
/// IPC table): per-destination-link load plus NIC spill-over.
#[derive(Clone, Debug)]
pub struct FanoutShape {
    /// Per destination GPU link: (locality, total bytes, transfer count).
    pub per_link: Vec<(Locality, usize, usize)>,
    /// Bytes bound for unreachable (inter-node) members.
    pub nic_bytes: usize,
    /// Total number of destination peers.
    pub npeers: usize,
    /// Representative locality for the adaptive bucket (the most distant
    /// reachable member; `SameNode` when links are in play).
    pub loc: Locality,
}

impl FanoutShape {
    /// Total bytes this fan-out moves (all links + NIC spill-over).
    pub fn total_bytes(&self) -> usize {
        self.per_link.iter().map(|&(_, b, _)| b).sum::<usize>() + self.nic_bytes
    }
}

impl Default for FanoutShape {
    fn default() -> Self {
        FanoutShape {
            per_link: Vec::new(),
            nic_bytes: 0,
            npeers: 0,
            loc: Locality::SameNode,
        }
    }
}

// ------------------------------------------------------- plan cache ------

/// Knobs for the planner's memoized structural plans (`plan_cache.*` in
/// `IshmemConfig`): `enable` turns the cache off entirely (planning is
/// then recomputed from the model on every op — bit-for-bit the same
/// plans, just slower), `capacity` bounds the total cached entries
/// across all shards.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanCacheConfig {
    pub enable: bool,
    pub capacity: usize,
}

impl Default for PlanCacheConfig {
    fn default() -> Self {
        PlanCacheConfig { enable: true, capacity: 4096 }
    }
}

/// Cache key: everything the *structural* part of a point-to-point plan
/// depends on besides the learned params. Exact `bytes` (not a size
/// class) so a hit reproduces the uncached plan bitwise. `OpKind` is
/// deliberately absent — it never enters the estimates. The learned-param
/// generation is stamped on the entry, not the key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    reachable: bool,
    loc: Locality,
    bytes: usize,
    items: usize,
    /// Canonical-layout digest for fan-out plans (0 for point-to-point):
    /// a [`fast_hash`] over the per-link `(loc, bytes, count)` tuples plus
    /// the NIC spill-over and peer count. Two fan-outs with the same
    /// digest share structural estimates; a 64-bit collision between two
    /// *different* layouts of identical (loc, bytes, items) is the
    /// accepted (astronomically unlikely) failure mode.
    shape: u64,
}

/// [`PlanKey::shape`] digest of a fan-out's canonical layout.
fn fanout_digest(shape: &FanoutShape) -> u64 {
    fast_hash(&(&shape.per_link, shape.nic_bytes, shape.npeers)).max(1)
}

/// One stage of a triggered chain, as the planner prices it: where the
/// stage's payload goes and how big it is. Signal-update stages are one
/// word (`bytes = 8`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ChainStage {
    /// IPC-table verdict for the stage's target (false ⇒ NIC route).
    pub reachable: bool,
    pub loc: Locality,
    pub bytes: usize,
}

/// [`PlanKey::shape`] digest of a triggered chain's stage list. The
/// `"chain"` tag keeps the digest domain disjoint from fan-out layouts
/// that could otherwise share a key.
fn chain_digest(stages: &[ChainStage]) -> u64 {
    fast_hash(&("chain", stages)).max(1)
}

/// The memoized pure portion of a plan: stripe geometry plus zero-backlog
/// estimates. Everything occupancy- or adaptive-dependent (engine/rail
/// drain terms, the route decision itself, ε-exploration draws) is
/// re-applied live on every hit, so cached and uncached planning agree
/// exactly — including side effects on the adaptive table.
#[derive(Clone, Copy, Debug)]
struct CachedShape {
    chunk: usize,
    width: usize,
    /// Load/store path estimate (0.0 for unreachable targets, which have
    /// no intra-node alternative).
    ls_ns: f64,
    /// Chosen-lane pure estimate: the striped engine pipeline for
    /// reachable targets, the rail-striped RDMA for remote ones. No
    /// occupancy terms.
    pure_ns: f64,
}

#[derive(Clone, Copy, Debug)]
struct CacheEntry {
    shape: CachedShape,
    /// Learned-params generation the shape was priced under.
    model_version: u64,
    /// The CL boundary is re-seedable *without* a version bump
    /// (`seed_cl_boundary`), so it stamps separately.
    cl_boundary: usize,
    /// Lane-health generation (dead/revived rails and engines) the shape
    /// was priced under — a kill re-stripes new plans onto the survivors,
    /// so cached widths from the healthy world must not be served.
    health_gen: u64,
}

/// Sharded memo of structural plans. Lock-light: 8 shards keyed by
/// [`fast_hash`], each a small mutexed map; generation churn is detected
/// by relaxed stamps and flushes wholesale, with a per-entry stamp check
/// as the backstop for racing writers holding older snapshots.
#[derive(Debug)]
struct PlanCache {
    cfg: PlanCacheConfig,
    shards: Vec<Mutex<HashMap<PlanKey, CacheEntry, FastState>>>,
    /// Per-shard entry cap derived from `cfg.capacity`.
    shard_cap: usize,
    /// Generation the cached population was priced under (relaxed — the
    /// per-entry stamps make any race benign).
    stamp_version: AtomicU64,
    stamp_boundary: AtomicU64,
    stamp_health: AtomicU64,
}

const CACHE_SHARDS: usize = 8;

impl PlanCache {
    fn new(cfg: PlanCacheConfig) -> Self {
        let shard_cap = cfg.capacity.div_ceil(CACHE_SHARDS).max(1);
        PlanCache {
            cfg,
            shards: (0..CACHE_SHARDS)
                .map(|_| Mutex::new(HashMap::with_hasher(FastState)))
                .collect(),
            shard_cap,
            stamp_version: AtomicU64::new(0),
            stamp_boundary: AtomicU64::new(0),
            stamp_health: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &PlanKey) -> &Mutex<HashMap<PlanKey, CacheEntry, FastState>> {
        &self.shards[(fast_hash(key) as usize) % CACHE_SHARDS]
    }

    /// Flush the whole population when the learned-params generation (or
    /// the separately re-seedable CL boundary, or the lane-health
    /// generation) moved since the cache was filled. Two planners racing
    /// with different snapshots at worst flush twice; a stale writer that
    /// sneaks an old-generation entry in afterwards is caught by the
    /// per-entry stamp on its next lookup.
    fn sync_generation(&self, snap: &ParamsSnapshot, health: u64, metrics: &Metrics) {
        let v = snap.version;
        let b = snap.params.cl_immediate_max_bytes as u64;
        if self.stamp_version.load(Ordering::Relaxed) == v
            && self.stamp_boundary.load(Ordering::Relaxed) == b
            && self.stamp_health.load(Ordering::Relaxed) == health
        {
            return;
        }
        let mut dropped = 0u64;
        for shard in &self.shards {
            let mut m = shard.lock().unwrap();
            dropped += m.len() as u64;
            m.clear();
        }
        self.stamp_version.store(v, Ordering::Relaxed);
        self.stamp_boundary.store(b, Ordering::Relaxed);
        self.stamp_health.store(health, Ordering::Relaxed);
        if dropped > 0 {
            Metrics::add(&metrics.plan_cache_invalidations, dropped);
        }
    }

    fn lookup(
        &self,
        snap: &ParamsSnapshot,
        health: u64,
        key: &PlanKey,
        metrics: &Metrics,
    ) -> Option<CachedShape> {
        if !self.cfg.enable {
            return None;
        }
        self.sync_generation(snap, health, metrics);
        let boundary = snap.params.cl_immediate_max_bytes;
        let mut shard = self.shard(key).lock().unwrap();
        match shard.get(key) {
            Some(e)
                if e.model_version == snap.version
                    && e.cl_boundary == boundary
                    && e.health_gen == health =>
            {
                let s = e.shape;
                drop(shard);
                Metrics::add(&metrics.plan_cache_hits, 1);
                Some(s)
            }
            Some(_) => {
                shard.remove(key);
                drop(shard);
                Metrics::add(&metrics.plan_cache_invalidations, 1);
                Metrics::add(&metrics.plan_cache_misses, 1);
                None
            }
            None => {
                drop(shard);
                Metrics::add(&metrics.plan_cache_misses, 1);
                None
            }
        }
    }

    fn insert(
        &self,
        snap: &ParamsSnapshot,
        health: u64,
        key: PlanKey,
        shape: CachedShape,
        metrics: &Metrics,
    ) {
        if !self.cfg.enable {
            return;
        }
        let mut shard = self.shard(&key).lock().unwrap();
        if shard.len() >= self.shard_cap {
            // Wholesale shard reset beats LRU bookkeeping on this path:
            // the steady-state working set (distinct transfer shapes) is
            // tiny next to the default capacity, so this fires ~never.
            let dropped = shard.len() as u64;
            shard.clear();
            Metrics::add(&metrics.plan_cache_invalidations, dropped);
        }
        shard.insert(
            key,
            CacheEntry {
                shape,
                model_version: snap.version,
                cl_boundary: snap.params.cl_immediate_max_bytes,
                health_gen: health,
            },
        );
    }

    /// Live entry count (tests / reports).
    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

/// The unified transfer-plan engine: one per machine, shared by all PEs.
#[derive(Debug)]
pub struct XferEngine {
    pub cost: Arc<CostModel>,
    pub cutover: CutoverConfig,
    /// Whether device-initiated engine transfers may use immediate command
    /// lists (§III-C) — affects the modeled startup constant. With the
    /// per-op CL policy below this is the *enable* bit: false forces
    /// standard lists everywhere (the ablation knob).
    pub immediate_cl: bool,
    /// Largest chunk the staging pipeline can double-buffer through the
    /// slab (set from `staging_slab_bytes` at machine construction). The
    /// stripe planner never picks chunks above this, so estimates and the
    /// executor's slicing agree.
    pub chunk_max_bytes: usize,
    adaptive: AdaptiveTable,
    cache: PlanCache,
    metrics: Arc<Metrics>,
}

/// Default chunk cap mirroring `IshmemConfig`'s default staging slab
/// (2 MiB double-buffered) for engines built without a machine.
const DEFAULT_CHUNK_MAX_BYTES: usize = 1 << 20;

impl XferEngine {
    pub fn new(
        cost: Arc<CostModel>,
        cutover: CutoverConfig,
        immediate_cl: bool,
        metrics: Arc<Metrics>,
    ) -> Self {
        let alpha = cutover.ema_alpha;
        let eps = cutover.explore_eps;
        XferEngine {
            cost,
            cutover,
            immediate_cl,
            chunk_max_bytes: DEFAULT_CHUNK_MAX_BYTES,
            adaptive: AdaptiveTable::new(alpha).with_exploration(eps),
            cache: PlanCache::new(PlanCacheConfig::default()),
            metrics,
        }
    }

    /// Install the plan-cache knobs (`plan_cache.*`). Rebuilds the cache
    /// empty — machine construction time only.
    pub fn set_plan_cache(&mut self, cfg: PlanCacheConfig) {
        self.cache = PlanCache::new(cfg);
    }

    /// Live cached-entry count (tests / reports).
    pub fn plan_cache_len(&self) -> usize {
        self.cache.len()
    }

    // ------------------------------------------------------ p2p planning --

    /// The live per-op command-list boundary (§III-C): batched descriptors
    /// at or below this size use an immediate list (low latency), larger
    /// ones a standard list (append → close → execute). `usize::MAX`
    /// reproduces the old global-immediate behavior. The value lives in
    /// the shared `ModelParams` store — it is the *third learned quantity*
    /// of the calibration loop, nudged toward the observed immediate-vs-
    /// standard crossover.
    pub fn cl_immediate_max_bytes(&self) -> usize {
        self.cost.model.get().cl_immediate_max_bytes
    }

    /// Configure (re-seed) the CL boundary at machine construction. Not a
    /// calibration event: the `ModelParams` version does not move.
    pub fn set_cl_immediate_max_bytes(&self, bytes: usize) {
        self.cost.model.seed_cl_boundary(bytes);
    }

    /// Per-op command-list choice for a `bytes`-sized engine transfer —
    /// the single policy point shared by the planner's estimates and the
    /// executors' descriptor flags (so modeled decisions and charges use
    /// the same startup constant).
    pub fn cl_immediate_for(&self, bytes: usize) -> bool {
        self.cl_immediate_for_at(&self.cost.model.snapshot(), bytes)
    }

    /// [`Self::cl_immediate_for`] against one caller-held snapshot.
    pub fn cl_immediate_for_at(&self, snap: &ParamsSnapshot, bytes: usize) -> bool {
        self.immediate_cl && bytes <= snap.params.cl_immediate_max_bytes
    }

    /// Model the point-to-point load/store path (pure estimate; touches
    /// no learned params, so there is no `_at` flavour).
    pub fn est_loadstore_ns(&self, loc: Locality, bytes: usize, items: usize) -> f64 {
        self.cost.loadstore_ns(loc, bytes, items)
    }

    /// The per-op CL boundary as the stripe scanner sees it: descriptors
    /// at or below this size run immediate command lists (0 when the
    /// global immediate enable bit is off).
    pub fn cl_immediate_boundary(&self) -> usize {
        self.cl_immediate_boundary_at(&self.cost.model.snapshot())
    }

    /// [`Self::cl_immediate_boundary`] against one caller-held snapshot.
    pub fn cl_immediate_boundary_at(&self, snap: &ParamsSnapshot) -> usize {
        if self.immediate_cl {
            snap.params.cl_immediate_max_bytes
        } else {
            0
        }
    }

    /// The (chunk size, stripe width) this engine's executor would use
    /// for an engine-path transfer of `bytes` — the cost model's stripe
    /// planner under this machine's staging-slab chunk cap and CL
    /// boundary (candidates are scored at the startup flavor their
    /// chunks will actually use).
    pub fn stripe_for(&self, loc: Locality, bytes: usize) -> (usize, usize) {
        self.stripe_for_at(&self.cost.model.snapshot(), loc, bytes)
    }

    /// [`Self::stripe_for`] against one caller-held snapshot.
    pub fn stripe_for_at(&self, snap: &ParamsSnapshot, loc: Locality, bytes: usize) -> (usize, usize) {
        self.cost.stripe_for_at(
            &snap.params,
            loc,
            bytes,
            self.chunk_max_bytes,
            self.cl_immediate_boundary_at(snap),
        )
    }

    /// Estimate of the engine path for an already-chosen stripe shape:
    /// ring round trip + the striped chunk pipeline at this engine's CL
    /// flavour (same formula as [`CostModel::p2p_engine_estimate_capped_ns`],
    /// without re-running the width scan). Snapshot-threaded: the CL
    /// choice and the effective engine params come from the same learned
    /// generation, so a calibration landing mid-estimate cannot tear it.
    fn est_engine_striped_ns_at(
        &self,
        snap: &ParamsSnapshot,
        loc: Locality,
        bytes: usize,
        chunk: usize,
        width: usize,
    ) -> f64 {
        let n = bytes.max(1).div_ceil(chunk.max(1));
        self.cost.ring_rtt_ns()
            + self.cost.ce_eff_at(&snap.params).striped_transfer_ns(
                &self.cost.params.xe,
                loc,
                bytes,
                self.cl_immediate_for_at(snap, chunk),
                false,
                width,
                n,
            )
    }

    /// Model the point-to-point engine path: ring round trip + the striped
    /// chunk pipeline (pure estimate, no queueing). Shares the stripe
    /// planner and formula with the policy-level reference in `cutover.rs`
    /// (which probes uncapped).
    pub fn est_copy_engine_ns(&self, loc: Locality, bytes: usize) -> f64 {
        let snap = self.cost.model.snapshot();
        let (chunk, width) = self.stripe_for_at(&snap, loc, bytes);
        self.est_engine_striped_ns_at(&snap, loc, bytes, chunk, width)
    }

    /// Occupancy-aware engine estimate: folds the source GPU's live
    /// copy-engine byte backlog into the pure estimate, so planning shifts
    /// toward load/store while the engine queue is loaded. `None` (no
    /// known source GPU — policy probes, tests) degrades to the pure
    /// estimate.
    pub fn est_copy_engine_loaded_ns(
        &self,
        src_gpu: Option<usize>,
        loc: Locality,
        bytes: usize,
    ) -> f64 {
        let snap = self.cost.model.snapshot();
        let backlog = src_gpu.map_or(0, |g| self.cost.engine_backlog_bytes(g));
        let (chunk, width) = self.stripe_for_at(&snap, loc, bytes);
        self.est_engine_striped_ns_at(&snap, loc, bytes, chunk, width)
            + self.cost.engine_drain_ns_at(&snap.params, loc, backlog)
    }

    /// The (chunk size, rail width) this engine's executor would use for
    /// an inter-node transfer of `bytes` — the cost model's rail stripe
    /// planner under this machine's staging-slab chunk cap (remote chunks
    /// stage through the same slab the engine pipeline double-buffers).
    pub fn rail_stripe_for(&self, bytes: usize) -> (usize, usize) {
        self.rail_stripe_for_at(&self.cost.model.snapshot(), bytes)
    }

    /// [`Self::rail_stripe_for`] against one caller-held snapshot.
    pub fn rail_stripe_for_at(&self, snap: &ParamsSnapshot, bytes: usize) -> (usize, usize) {
        self.cost.rail_stripe_for_at(&snap.params, bytes, self.chunk_max_bytes)
    }

    /// Estimate of the inter-node path for an already-chosen rail stripe
    /// shape: ring round trip + host proxy + the rail-striped RDMA
    /// (registered-heap assumption, like every planning estimate).
    fn est_nic_striped_ns_at(
        &self,
        snap: &ParamsSnapshot,
        bytes: usize,
        chunk: usize,
        width: usize,
    ) -> f64 {
        let n = bytes.max(1).div_ceil(chunk.max(1));
        self.cost
            .internode_striped_ns_at(&snap.params, bytes, true, true, width, n)
    }

    /// Model the inter-node path (registered-heap RDMA estimate) at the
    /// rail stripe shape the executor would use. A 1-rail configuration
    /// reproduces the pre-striping single-RDMA estimate exactly.
    pub fn est_nic_ns(&self, bytes: usize) -> f64 {
        let snap = self.cost.model.snapshot();
        let (chunk, width) = self.rail_stripe_for_at(&snap, bytes);
        self.est_nic_striped_ns_at(&snap, bytes, chunk, width)
    }

    // -------------------------------------------------- chain planning --

    /// Pure exec estimate of one chain stage: the zero-backlog striped
    /// pipeline for the stage's route, *without* the ring round trip —
    /// a fused chain pays one doorbell for the whole chain, so the RTT
    /// is accounted once by the caller, not per stage.
    fn est_stage_exec_ns_at(&self, snap: &ParamsSnapshot, s: &ChainStage) -> f64 {
        if !s.reachable {
            let (chunk, width) = self.rail_stripe_for_at(snap, s.bytes);
            let n = s.bytes.max(1).div_ceil(chunk.max(1));
            self.cost
                .internode_striped_ns_at(&snap.params, s.bytes, true, false, width, n)
        } else {
            let (chunk, width) = self.stripe_for_at(snap, s.loc, s.bytes);
            let n = s.bytes.max(1).div_ceil(chunk.max(1));
            self.cost.ce_eff_at(&snap.params).striped_transfer_ns(
                &self.cost.params.xe,
                s.loc,
                s.bytes,
                self.cl_immediate_for_at(snap, chunk),
                false,
                width,
                n,
            )
        }
    }

    /// The memoized chain shape: `pure_ns` is the fused estimate (ONE
    /// ring round trip + per-stage zero-backlog exec back-to-back on the
    /// proxy), `ls_ns` the sequential one (each stage its own doorbell).
    /// Keyed by the stage-list digest; the same cache stamps (params
    /// version, CL boundary, planning generation) guard staleness.
    fn chain_shape_at(&self, snap: &ParamsSnapshot, stages: &[ChainStage]) -> CachedShape {
        let total: usize = stages.iter().map(|s| s.bytes).sum();
        let key = PlanKey {
            reachable: stages.iter().all(|s| s.reachable),
            loc: stages.first().map_or(Locality::SameNode, |s| s.loc),
            bytes: total,
            items: stages.len(),
            shape: chain_digest(stages),
        };
        let health = self.cost.planning_generation();
        if let Some(s) = self.cache.lookup(snap, health, &key, &self.metrics) {
            return s;
        }
        let rtt = self.cost.ring_rtt_ns();
        let mut fused = rtt;
        let mut seq = 0.0;
        for st in stages {
            let exec = self.est_stage_exec_ns_at(snap, st);
            fused += exec;
            seq += rtt + exec;
        }
        let s = CachedShape {
            chunk: total,
            width: stages.len().max(1),
            ls_ns: seq,
            pure_ns: fused,
        };
        self.cache.insert(snap, health, key, s, &self.metrics);
        s
    }

    /// Model a depth-d triggered chain submitted as one fused batch: one
    /// ring round trip, then the stages execute in dependency order on
    /// the proxy with no further host crossings (ISSUE 10).
    pub fn est_chain_ns(&self, stages: &[ChainStage]) -> f64 {
        self.chain_shape_at(&self.cost.model.snapshot(), stages).pure_ns
    }

    /// Model the same stages submitted sequentially: every stage its own
    /// doorbell (one ring round trip each) — the pre-chain baseline the
    /// fused estimate is compared against.
    pub fn est_chain_sequential_ns(&self, stages: &[ChainStage]) -> f64 {
        self.chain_shape_at(&self.cost.model.snapshot(), stages).ls_ns
    }

    /// Fuse-vs-flush policy point for a chain: fuse when the one-doorbell
    /// estimate is no worse than the sequential submission. Structurally
    /// fusing saves `d-1` round trips so this is nearly always true, but
    /// the decision stays a model comparison (and both sides are priced
    /// under one snapshot), not an axiom.
    pub fn chain_fuse_wins(&self, stages: &[ChainStage]) -> bool {
        let s = self.chain_shape_at(&self.cost.model.snapshot(), stages);
        s.pure_ns <= s.ls_ns
    }

    /// The structural (pure, learned-generation-determined) portion of a
    /// point-to-point plan: cache hit, or compute-and-fill.
    fn shape_for(
        &self,
        snap: &ParamsSnapshot,
        reachable: bool,
        loc: Locality,
        bytes: usize,
        items: usize,
    ) -> CachedShape {
        // The "health" stamp is the *planning* generation: lane liveness
        // folded with the retry strike picture, so a strike (or a
        // forgiveness) flushes cached shapes priced under the old
        // penalties. Strike-free runs never move it past the pure health
        // generation — zero extra invalidations on the happy path.
        let key = PlanKey { reachable, loc, bytes, items, shape: 0 };
        let health = self.cost.planning_generation();
        if let Some(s) = self.cache.lookup(snap, health, &key, &self.metrics) {
            return s;
        }
        let s = self.compute_shape(snap, reachable, loc, bytes, items);
        self.cache.insert(snap, health, key, s, &self.metrics);
        s
    }

    /// One width scan + the pure path estimates, all against one snapshot.
    /// This is the uncached planning body *and* the cache-fill path — a
    /// single function, so cached and uncached plans cannot diverge.
    fn compute_shape(
        &self,
        snap: &ParamsSnapshot,
        reachable: bool,
        loc: Locality,
        bytes: usize,
        items: usize,
    ) -> CachedShape {
        if !reachable {
            let (chunk, width) = self.rail_stripe_for_at(snap, bytes);
            CachedShape {
                chunk,
                width,
                ls_ns: 0.0,
                pure_ns: self.est_nic_striped_ns_at(snap, bytes, chunk, width),
            }
        } else {
            let (chunk, width) = self.stripe_for_at(snap, loc, bytes);
            CachedShape {
                chunk,
                width,
                ls_ns: self.est_loadstore_ns(loc, bytes, items),
                pure_ns: self.est_engine_striped_ns_at(snap, loc, bytes, chunk, width),
            }
        }
    }

    /// Plan a point-to-point transfer of `bytes` to a `loc`-distant PE by
    /// `items` cooperating work-items. `reachable` is the IPC-table verdict
    /// (§III-G.1 step 2): unreachable targets always route to the NIC.
    /// Occupancy-blind (no source GPU known) — the live path uses
    /// [`Self::plan_p2p_from`].
    pub fn plan_p2p(
        &self,
        kind: OpKind,
        reachable: bool,
        loc: Locality,
        bytes: usize,
        items: usize,
    ) -> TransferPlan {
        self.plan_p2p_from(None, kind, reachable, loc, bytes, items)
    }

    /// Plan a point-to-point transfer issued from `src_gpu` (global GPU
    /// index): the engine-path estimate folds that GPU's live engine-queue
    /// byte backlog, so cutover decisions shift under load.
    pub fn plan_p2p_from(
        &self,
        src_gpu: Option<usize>,
        kind: OpKind,
        reachable: bool,
        loc: Locality,
        bytes: usize,
        items: usize,
    ) -> TransferPlan {
        // One snapshot covers the whole plan: every estimate term, the
        // decision's cell aging and the plan stamp are priced under the
        // same learned generation even if a calibration lands mid-plan.
        // (Estimates priced a recalibration later than this read
        // self-heal: the next decision at the newer version re-seeds the
        // touched cell.) The structural portion — width scans and pure
        // estimates, a pure function of (key, snapshot) — comes from the
        // plan cache; the occupancy terms and the route decision are
        // always re-applied live, so a hit is bitwise the uncached plan.
        let snap = self.cost.model.snapshot();
        let shape = self.shape_for(&snap, reachable, loc, bytes, items);
        if !reachable {
            // Rail-striped remote shape: the source node's live rail
            // backlog folds into the modeled cost (the remote twin of the
            // engine-queue occupancy fold — there is no alternative
            // route, but adaptive feedback and reports see the load).
            let rail_backlog = src_gpu.map_or(0, |g| {
                self.cost
                    .rail_backlog_bytes(g / self.cost.topo.gpus_per_node.max(1))
            });
            // Every rail on the source node dead: there is no alternative
            // route for an unreachable peer, so the plan still ships over
            // the (degenerate, width-1) NIC path — counted, not panicked.
            if self.cost.degraded() {
                if let Some(g) = src_gpu {
                    let node = g / self.cost.topo.gpus_per_node.max(1);
                    if self.cost.rail_live_count(node) == 0 {
                        Metrics::add(&self.metrics.fault_last_lane_fallbacks, 1);
                    }
                }
            }
            let plan = TransferPlan {
                kind,
                loc: Locality::Remote,
                bytes,
                items,
                peers: 1,
                route: Route::Nic,
                modeled_ns: shape.pure_ns
                    + self.cost.rail_drain_ns_at(&snap.params, rail_backlog),
                alt_ns: None,
                chunk_bytes: shape.chunk,
                stripe_width: shape.width,
                model_version: snap.version,
            };
            self.count_plan(plan.route);
            return plan;
        }
        let backlog = src_gpu.map_or(0, |g| self.cost.engine_backlog_bytes(g));
        let ls = shape.ls_ns;
        let ce = shape.pure_ns + self.cost.engine_drain_ns_at(&snap.params, loc, backlog);
        // Every copy engine on the source GPU dead: skip the cutover
        // decision entirely and fall back to the raw-pointer load/store
        // path (which needs no engines) — counted, not panicked.
        if self.cost.degraded() {
            if let Some(g) = src_gpu {
                if self.cost.engine_live_count(g) == 0 {
                    Metrics::add(&self.metrics.fault_last_lane_fallbacks, 1);
                    let plan = self.bind(kind, loc, bytes, items, 1, Path::LoadStore, ls, ce, snap.version);
                    self.count_plan(plan.route);
                    return plan;
                }
            }
        }
        let path = self.decide(BucketKey::p2p(loc, bytes, items), bytes, ls, ce, snap.version);
        let mut plan = self.bind(kind, loc, bytes, items, 1, path, ls, ce, snap.version);
        if plan.route == Route::CopyEngine {
            plan.chunk_bytes = shape.chunk;
            plan.stripe_width = shape.width;
        }
        self.count_plan(plan.route);
        plan
    }

    // -------------------------------------------------- fan-out planning --

    /// Modeled duration of fanning `shape` out via work-item stores: links
    /// run in parallel, work-items split across active links, multiple
    /// peers behind one link serialize (paper Fig 6 discussion).
    pub fn fanout_store_ns(&self, shape: &FanoutShape, items: usize) -> f64 {
        if shape.npeers == 0 || shape.total_bytes() == 0 {
            return 0.0;
        }
        let active = shape.per_link.len().max(1);
        let items_per_link = (items / active).max(1);
        let xe = &self.cost.params.xe;
        let mut t: f64 = 0.0;
        for &(loc, link_bytes, _) in &shape.per_link {
            t = t.max(xe.loadstore_ns(loc, link_bytes, items_per_link));
        }
        if shape.nic_bytes > 0 {
            t = t.max(self.cost.internode_ns(shape.nic_bytes, true, true));
        }
        self.cost.device_issue_ns() + t
    }

    /// Modeled duration of the same fan-out via copy engines started by a
    /// single reverse-offload up-call: engines run in parallel up to the
    /// per-GPU engine count, links still share bandwidth.
    pub fn fanout_engine_ns(&self, shape: &FanoutShape) -> f64 {
        self.fanout_engine_ns_at(&self.cost.model.snapshot(), shape)
    }

    /// [`Self::fanout_engine_ns`] against one caller-held snapshot: the
    /// engine constants and the rail-spillover terms all price under the
    /// same learned generation. Memoized by [`Self::plan_fanout`] via the
    /// plan cache (collectives loops replay the same layout every
    /// iteration); this body is the cache-fill path.
    fn fanout_engine_ns_at(&self, snap: &ParamsSnapshot, shape: &FanoutShape) -> f64 {
        if shape.npeers == 0 || shape.total_bytes() == 0 {
            return 0.0;
        }
        let ce = self.cost.ce_eff_at(&snap.params);
        let xe = &self.cost.params.xe;
        // Dead copy engines shrink the fan-out's parallelism floor — the
        // healthy fast path leaves the configured count untouched, so the
        // fault-free estimate is bit-identical to the pre-fault code.
        let engines = ce.engines_per_gpu.min(self.cost.min_live_engines());
        let mut t: f64 = 0.0;
        for &(loc, link_bytes, transfers) in &shape.per_link {
            // Startup overlaps across engines; transfers on one link share
            // its bandwidth. The executor stripes each block's chunks over
            // the engines, so the link runs at the aggregate engine rate
            // (capped at the physical link).
            let startups = transfers.div_ceil(engines) as f64;
            t = t.max(
                startups * ce.startup_immediate_ns
                    + link_bytes as f64 / ce.striped_bw_gbs(xe, loc, engines),
            );
        }
        if shape.nic_bytes > 0 {
            // Remote spill-over of an engine-branch fan-out chunks across
            // the NIC rails (same stripe planner as p2p remote puts; a
            // 1-rail config degenerates to the single-RDMA estimate).
            let (chunk, width) = self
                .cost
                .rail_stripe_for_at(&snap.params, shape.nic_bytes, usize::MAX);
            let n = shape.nic_bytes.div_ceil(chunk.max(1));
            t = t.max(self.cost.internode_striped_ns_at(
                &snap.params,
                shape.nic_bytes,
                true,
                false,
                width,
                n,
            ));
        }
        self.cost.ring_rtt_ns() + t
    }

    /// Plan a collective fan-out of `bytes` per peer by `items` work-items
    /// (paper Fig 6: the decision depends on nelems, work-items *and* the
    /// PE count — all captured by the shape).
    pub fn plan_fanout(&self, shape: &FanoutShape, bytes: usize, items: usize) -> TransferPlan {
        let snap = self.cost.model.snapshot();
        // Fan-out layouts repeat heavily inside collectives loops (same
        // team + same block size ⇒ same per-link vector every iteration),
        // so the structural estimates memoize like p2p plans, keyed by the
        // canonical-layout digest. Both sides are pure functions of
        // (layout, items, snapshot); the route decision and its adaptive
        // side effects stay live, so a hit plans bitwise like a miss.
        let key = PlanKey {
            reachable: true,
            loc: shape.loc,
            bytes,
            items,
            shape: fanout_digest(shape),
        };
        let health = self.cost.planning_generation();
        let s = self.cache.lookup(&snap, health, &key, &self.metrics).unwrap_or_else(|| {
            let s = CachedShape {
                chunk: bytes,
                width: 1,
                ls_ns: self.fanout_store_ns(shape, items),
                pure_ns: self.fanout_engine_ns_at(&snap, shape),
            };
            self.cache.insert(&snap, health, key, s, &self.metrics);
            s
        });
        let (ls, ce) = (s.ls_ns, s.pure_ns);
        let key = BucketKey::fanout(shape.loc, bytes, items, shape.npeers);
        let path = self.decide(key, bytes, ls, ce, snap.version);
        let plan = self.bind(
            OpKind::Fanout,
            shape.loc,
            bytes,
            items,
            shape.npeers,
            path,
            ls,
            ce,
            snap.version,
        );
        self.count_plan(plan.route);
        plan
    }

    // ---------------------------------------------------------- feedback --

    /// Feed back the observed (modeled, queue-aware) duration of an
    /// executed plan. Under `Adaptive` this refines the learned table;
    /// the metric counts only observations that actually refined a cell
    /// (a fixed-threshold override never seeds cells, for example).
    pub fn record(&self, plan: &TransferPlan, observed_ns: f64) {
        if self.cutover.mode != CutoverMode::Adaptive {
            return;
        }
        if let Some(path) = plan.route.as_path() {
            // The plan's own version guards the feedback: an observation
            // priced under a pre-recalibration model never refines a cell
            // that was re-seeded since.
            if self.adaptive.observe(plan.bucket(), path, observed_ns, plan.model_version) {
                Metrics::add(&self.metrics.adaptive_updates, 1);
            }
        }
    }

    /// The learned table (reports / benches / tests).
    pub fn adaptive_snapshot(&self) -> Vec<AdaptiveCell> {
        self.adaptive.snapshot()
    }

    // ------------------------------------- collective algorithm choice --

    /// Decide flat vs hierarchical for a collective through the same
    /// cutover machinery as p2p routing: one adaptive cell per (op, size,
    /// team-size bucket), slot 0 (`LoadStore`) pricing the flat fan-out
    /// and slot 1 (`CopyEngine`) the chosen hierarchical variant, seeded
    /// from the caller's snapshot-priced estimates so calibration feeds
    /// back into algorithm choice. Non-adaptive modes take the model
    /// argmin — `Never`/`Always`/`fixed_threshold` are load/store-vs-
    /// engine *path* policies and do not constrain algorithm shape.
    /// Returns true for hierarchical.
    pub fn coll_decide(
        &self,
        op: CollOp,
        bytes: usize,
        team_size: usize,
        flat_ns: f64,
        hier_ns: f64,
        model_version: u64,
    ) -> bool {
        let path = if self.cutover.mode == CutoverMode::Adaptive {
            self.adaptive
                .decide(BucketKey::coll(op, bytes, team_size), flat_ns, hier_ns, model_version)
        } else {
            argmin_path(flat_ns, hier_ns)
        };
        path == Path::CopyEngine
    }

    /// Feed back an executed collective's total modeled duration into its
    /// algorithm cell (adaptive mode only) — the collective twin of
    /// [`Self::record`].
    pub fn coll_observe(
        &self,
        op: CollOp,
        bytes: usize,
        team_size: usize,
        took_hier: bool,
        observed_ns: f64,
        model_version: u64,
    ) {
        if self.cutover.mode != CutoverMode::Adaptive {
            return;
        }
        let path = if took_hier { Path::CopyEngine } else { Path::LoadStore };
        let key = BucketKey::coll(op, bytes, team_size);
        if self.adaptive.observe(key, path, observed_ns, model_version) {
            Metrics::add(&self.metrics.adaptive_updates, 1);
        }
    }

    // ------------------------------------------------ table persistence --

    /// Serialize the learned table as one JSON object (the
    /// `cutover.table_path` persistence format; reuses the hand-rolled
    /// Json writer behind `MetricsSnapshot::to_json`).
    pub fn adaptive_save_json(&self) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let cells: Vec<Json> = self
            .adaptive_snapshot()
            .iter()
            .map(|c| {
                let mut o: BTreeMap<String, Json> = BTreeMap::new();
                let mut put = |k: &str, v: Json| o.insert(k.to_string(), v);
                put("loc", Json::Num(c.key.loc as u8 as f64));
                put("size_pow2", Json::Num(c.key.size_pow2 as f64));
                put("items_pow2", Json::Num(c.key.items_pow2 as f64));
                put("fanout", Json::Bool(c.key.fanout));
                put("peers_pow2", Json::Num(c.key.peers_pow2 as f64));
                put("rails_pow2", Json::Num(c.key.rails_pow2 as f64));
                put("coll_op", Json::Num(c.key.coll_op as f64));
                put("ema_loadstore_ns", Json::Num(c.ema_loadstore_ns));
                put("ema_copy_engine_ns", Json::Num(c.ema_copy_engine_ns));
                put("samples_loadstore", Json::Num(c.samples_loadstore as f64));
                put("samples_copy_engine", Json::Num(c.samples_copy_engine as f64));
                Json::Obj(o)
            })
            .collect();
        let mut top: BTreeMap<String, Json> = BTreeMap::new();
        top.insert("ema_alpha".to_string(), Json::Num(self.cutover.ema_alpha));
        // ModelParams staleness header: the cells' EMAs were learned
        // against *these* hardware constants. The fingerprint is the
        // learned values themselves — the version counter is process-local
        // (every process starts at 0) and is stored only as information.
        // A loader whose live params differ discards the cells instead of
        // trusting EMAs priced under a hardware model it does not have.
        let live = self.cost.model.get();
        let mut fp: BTreeMap<String, Json> = BTreeMap::new();
        fp.insert("single_engine_frac".to_string(), Json::Num(live.single_engine_frac));
        fp.insert("startup_immediate_ns".to_string(), Json::Num(live.startup_immediate_ns));
        fp.insert("startup_standard_ns".to_string(), Json::Num(live.startup_standard_ns));
        fp.insert("rail_bw_frac".to_string(), Json::Num(live.rail_bw_frac));
        fp.insert("rail_startup_ns".to_string(), Json::Num(live.rail_startup_ns));
        fp.insert(
            "cl_immediate_max_bytes".to_string(),
            Json::Num(live.cl_immediate_max_bytes as f64),
        );
        top.insert("model_params".to_string(), Json::Obj(fp));
        top.insert(
            "model_version".to_string(),
            Json::Num(self.cost.model.version() as f64),
        );
        top.insert("cells".to_string(), Json::Arr(cells));
        Json::Obj(top).to_string()
    }

    /// Install learned cells from [`Self::adaptive_save_json`]'s format.
    /// Returns how many cells were loaded. A table saved under a
    /// different `ema_alpha` still installs (the EMAs are valid state,
    /// just smoothed under another time constant) — but the mismatch is
    /// surfaced, not swallowed. A table saved under **different
    /// `ModelParams`**, however, is *stale*: its EMAs were learned against
    /// another hardware model, so its cells are discarded (with a warning)
    /// and the load reports 0 cells — the cold-start seeds are more
    /// trustworthy than confidently-wrong learned state. The comparison is
    /// the `model_params` fingerprint (the learned values themselves, which
    /// survive process restarts), not the process-local version counter.
    /// Tables from before the calibration era carry no fingerprint and are
    /// trusted only by a machine whose live params still equal its seed
    /// (i.e. one that has not itself recalibrated).
    pub fn adaptive_load_json(&self, text: &str) -> anyhow::Result<usize> {
        use crate::util::json::Json;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("adaptive table: {e}"))?;
        let current = self.cost.model.version();
        let live = self.cost.model.get();
        let params_match = match j.get("model_params") {
            Some(fp) => {
                // f64 Display round-trips exactly, so bit-equality of the
                // re-parsed values is the right comparison.
                let f = |k: &str| fp.get(k).and_then(|v| v.as_f64());
                f("single_engine_frac") == Some(live.single_engine_frac)
                    && f("startup_immediate_ns") == Some(live.startup_immediate_ns)
                    && f("startup_standard_ns") == Some(live.startup_standard_ns)
                    && f("rail_bw_frac") == Some(live.rail_bw_frac)
                    && f("rail_startup_ns") == Some(live.rail_startup_ns)
                    && f("cl_immediate_max_bytes") == Some(live.cl_immediate_max_bytes as f64)
            }
            None => live == self.cost.model.seed(),
        };
        if !params_match {
            eprintln!(
                "warning: adaptive table was learned under different ModelParams than \
                 this machine's live values — discarding stale cells"
            );
            return Ok(0);
        }
        if let Some(saved_alpha) = j.get("ema_alpha").and_then(|v| v.as_f64()) {
            if (saved_alpha - self.cutover.ema_alpha).abs() > 1e-12 {
                eprintln!(
                    "warning: adaptive table was learned under ema_alpha {saved_alpha}, \
                     this machine refines with {}",
                    self.cutover.ema_alpha
                );
            }
        }
        let cells = j
            .get("cells")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("adaptive table: missing cells array"))?;
        let mut loaded = Vec::with_capacity(cells.len());
        for c in cells {
            let num = |k: &str| {
                c.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow::anyhow!("adaptive table: missing field {k}"))
            };
            let loc = match num("loc")? as u8 {
                0 => Locality::SameTile,
                1 => Locality::SameGpu,
                2 => Locality::SameNode,
                3 => Locality::Remote,
                other => anyhow::bail!("adaptive table: bad locality tag {other}"),
            };
            let fanout = matches!(c.get("fanout"), Some(Json::Bool(true)));
            loaded.push(AdaptiveCell {
                key: BucketKey {
                    loc,
                    size_pow2: num("size_pow2")? as u8,
                    items_pow2: num("items_pow2")? as u8,
                    fanout,
                    peers_pow2: num("peers_pow2")? as u8,
                    rails_pow2: num("rails_pow2")? as u8,
                    // Absent in pre-collective tables: those cells are all
                    // transfer cells (class 0).
                    coll_op: c.get("coll_op").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                },
                ema_loadstore_ns: num("ema_loadstore_ns")?,
                ema_copy_engine_ns: num("ema_copy_engine_ns")?,
                samples_loadstore: num("samples_loadstore")? as u64,
                samples_copy_engine: num("samples_copy_engine")? as u64,
                // The fingerprint matched this machine's live params, so
                // the cells install as current-model cells.
                model_version: current,
            });
        }
        self.adaptive.load_cells(&loaded);
        Ok(loaded.len())
    }

    /// Save the learned table to `path` (`cutover.table_path`).
    pub fn adaptive_save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.adaptive_save_json())
            .map_err(|e| anyhow::anyhow!("saving adaptive table to {path}: {e}"))
    }

    /// Load a previously-saved table from `path`; returns the cell count.
    pub fn adaptive_load(&self, path: &str) -> anyhow::Result<usize> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("loading adaptive table from {path}: {e}"))?;
        self.adaptive_load_json(&text)
    }

    /// Learned point-to-point crossover size for (loc, items): smallest
    /// power-of-two size the engine routes to the copy engines. Falls back
    /// to model seeds for untouched cells — i.e. cold cells answer like
    /// `Tuned`'s [`CutoverConfig::crossover_bytes`].
    pub fn learned_crossover_bytes(&self, loc: Locality, items: usize) -> Option<usize> {
        (3..28).map(|p| 1usize << p).find(|&b| {
            let key = BucketKey::p2p(loc, b, items);
            let path = self.adaptive.peek(key).unwrap_or_else(|| {
                argmin_path(
                    self.est_loadstore_ns(loc, b, items),
                    self.est_copy_engine_ns(loc, b),
                )
            });
            path == Path::CopyEngine
        })
    }

    /// The `Tuned` model's point-to-point crossover, computed from this
    /// engine's own estimates (honours `immediate_cl`) — the reference
    /// column the learned table is compared against. This is the single
    /// model formula; `CutoverConfig::crossover_bytes` remains only as
    /// the immediate-CL reference used by policy-level tests.
    pub fn model_crossover_bytes(&self, loc: Locality, items: usize) -> Option<usize> {
        (3..28).map(|p| 1usize << p).find(|&b| {
            argmin_path(
                self.est_loadstore_ns(loc, b, items),
                self.est_copy_engine_ns(loc, b),
            ) == Path::CopyEngine
        })
    }

    /// The model crossover when the source GPU's engines already hold
    /// `backlog_bytes` of queued work: the engine path pays the backlog
    /// drain, so the crossover moves right (or disappears) under load.
    pub fn model_crossover_bytes_loaded(
        &self,
        loc: Locality,
        items: usize,
        backlog_bytes: u64,
    ) -> Option<usize> {
        let snap = self.cost.model.snapshot();
        (3..28).map(|p| 1usize << p).find(|&b| {
            let (chunk, _) = self.stripe_for_at(&snap, loc, b);
            argmin_path(
                self.est_loadstore_ns(loc, b, items),
                self.cost.p2p_engine_estimate_capped_loaded_ns_at(
                    &snap.params,
                    loc,
                    b,
                    self.cl_immediate_for_at(&snap, chunk),
                    self.chunk_max_bytes,
                    backlog_bytes,
                ),
            ) == Path::CopyEngine
        })
    }

    /// Occupancy view of the cutover table: modeled crossovers at a few
    /// engine-queue backlog levels (`figure cutover-table` appendix; the
    /// acceptance check that planning is engine-queue aware).
    pub fn occupancy_crossover_report(&self) -> String {
        let backlogs: [(u64, &str); 4] = [
            (0, "idle"),
            (1 << 20, "1MiB"),
            (8 << 20, "8MiB"),
            (64 << 20, "64MiB"),
        ];
        let mut out = String::from(
            "occupancy-aware cutover: modeled crossover (bytes) vs engine backlog\n",
        );
        out.push_str("locality    items  ");
        for &(_, label) in &backlogs {
            out.push_str(&format!(" {label:<11}"));
        }
        out.push('\n');
        for loc in [Locality::SameTile, Locality::SameGpu, Locality::SameNode] {
            for items in [1usize, 16, 128, 1024] {
                let cells: Vec<String> = backlogs
                    .iter()
                    .map(|&(b, _)| {
                        self.model_crossover_bytes_loaded(loc, items, b)
                            .map_or("-".into(), |x| x.to_string())
                    })
                    .collect();
                out.push_str(&format!(
                    "{:<11} {:<7} {:<11} {:<11} {:<11} {:<11}\n",
                    format!("{loc:?}"),
                    items,
                    cells[0],
                    cells[1],
                    cells[2],
                    cells[3],
                ));
            }
        }
        out
    }

    /// Human-readable learned-vs-modeled crossover table (bench report).
    pub fn adaptive_report(&self) -> String {
        let mut out = String::from(
            "adaptive cutover: learned vs modeled crossover (bytes)\n\
             locality    items   learned     tuned-model\n",
        );
        for loc in [Locality::SameTile, Locality::SameGpu, Locality::SameNode] {
            for items in [1usize, 16, 128, 1024] {
                let learned = self.learned_crossover_bytes(loc, items);
                let tuned = self.model_crossover_bytes(loc, items);
                out.push_str(&format!(
                    "{:<11} {:<7} {:<11} {:<11}\n",
                    format!("{loc:?}"),
                    items,
                    learned.map_or("-".into(), |b| b.to_string()),
                    tuned.map_or("-".into(), |b| b.to_string()),
                ));
            }
        }
        let cells = self.adaptive.len();
        out.push_str(&format!("learned cells: {cells}\n"));
        out
    }

    // ---------------------------------------------------------- internals --

    /// Mode dispatch over pre-computed path estimates. This is the single
    /// cutover branch point for the whole library. The adaptive arm passes
    /// the live `ModelParams` version, so cells seeded before a
    /// recalibration age out (re-seed from the fresh estimates) the next
    /// time traffic touches them.
    fn decide(
        &self,
        key: BucketKey,
        bytes: usize,
        ls_ns: f64,
        ce_ns: f64,
        model_version: u64,
    ) -> Path {
        match self.cutover.mode {
            CutoverMode::Never => Path::LoadStore,
            CutoverMode::Always => Path::CopyEngine,
            CutoverMode::Tuned => {
                if let Some(t) = self.cutover.fixed_threshold {
                    return if bytes < t { Path::LoadStore } else { Path::CopyEngine };
                }
                argmin_path(ls_ns, ce_ns)
            }
            CutoverMode::Adaptive => {
                if let Some(t) = self.cutover.fixed_threshold {
                    return if bytes < t { Path::LoadStore } else { Path::CopyEngine };
                }
                self.adaptive.decide(key, ls_ns, ce_ns, model_version)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn bind(
        &self,
        kind: OpKind,
        loc: Locality,
        bytes: usize,
        items: usize,
        peers: usize,
        path: Path,
        ls_ns: f64,
        ce_ns: f64,
        model_version: u64,
    ) -> TransferPlan {
        let (route, modeled, alt) = match path {
            Path::LoadStore => (Route::LoadStore, ls_ns, ce_ns),
            Path::CopyEngine => (Route::CopyEngine, ce_ns, ls_ns),
        };
        TransferPlan {
            kind,
            loc,
            bytes,
            items,
            peers,
            route,
            modeled_ns: modeled,
            alt_ns: Some(alt),
            chunk_bytes: bytes,
            stripe_width: 1,
            model_version,
        }
    }

    fn count_plan(&self, route: Route) {
        let counter = match route {
            Route::LoadStore => &self.metrics.xfer_plans_loadstore,
            Route::CopyEngine => &self.metrics.xfer_plans_copy_engine,
            Route::Nic => &self.metrics.xfer_plans_nic,
        };
        Metrics::add(counter, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CostParams, Topology};

    fn engine(cfg: CutoverConfig) -> XferEngine {
        let cost = CostModel::new(Topology::default(), CostParams::default());
        XferEngine::new(cost, cfg, true, Metrics::new())
    }

    #[test]
    fn tuned_plan_picks_argmin_and_keeps_alternative() {
        let e = engine(CutoverConfig::tuned());
        for bytes in [64usize, 4096, 1 << 20] {
            let p = e.plan_p2p(OpKind::Put, true, Locality::SameNode, bytes, 1);
            let alt = p.alt_ns.unwrap();
            assert!(
                p.modeled_ns <= alt,
                "{bytes}B: chosen {} !<= alt {alt}",
                p.modeled_ns
            );
        }
    }

    #[test]
    fn unreachable_always_routes_nic() {
        let e = engine(CutoverConfig::never());
        let p = e.plan_p2p(OpKind::Put, false, Locality::Remote, 8, 1);
        assert_eq!(p.route, Route::Nic);
        assert!(p.alt_ns.is_none());
    }

    #[test]
    fn adaptive_seeds_like_tuned() {
        let tuned = engine(CutoverConfig::tuned());
        let adap = engine(CutoverConfig::adaptive());
        for p in 3..24 {
            let bytes = 1usize << p;
            for items in [1usize, 128] {
                let a = adap.plan_p2p(OpKind::Put, true, Locality::SameNode, bytes, items);
                let t = tuned.plan_p2p(OpKind::Put, true, Locality::SameNode, bytes, items);
                assert_eq!(a.route, t.route, "cold adaptive diverged at {bytes}B/{items}wi");
            }
        }
    }

    #[test]
    fn backlog_shifts_crossover_right() {
        let e = engine(CutoverConfig::tuned());
        let idle = e.model_crossover_bytes(Locality::SameNode, 1);
        let loaded = e.model_crossover_bytes_loaded(Locality::SameNode, 1, 64 << 20);
        assert_eq!(idle, e.model_crossover_bytes_loaded(Locality::SameNode, 1, 0));
        match (idle, loaded) {
            // A loaded queue must move the crossover strictly right (or
            // off the probed range entirely).
            (Some(i), Some(l)) => assert!(l > i, "loaded {l} !> idle {i}"),
            (Some(_), None) => {}
            other => panic!("unexpected crossovers {other:?}"),
        }
        // Live backlog feeds the same shift through plan_p2p_from.
        let bytes = idle.unwrap();
        e.cost.engine_reserve(0, 64 << 20);
        let p = e.plan_p2p_from(Some(0), OpKind::Put, true, Locality::SameNode, bytes, 1);
        assert_eq!(p.route, Route::LoadStore, "loaded queue kept engine route");
        e.cost.engine_release(0, 64 << 20);
        let p = e.plan_p2p_from(Some(0), OpKind::Put, true, Locality::SameNode, bytes, 1);
        assert_eq!(p.route, Route::CopyEngine, "idle queue lost engine route");
    }

    #[test]
    fn large_engine_plans_stripe_across_engines() {
        let e = engine(CutoverConfig::always());
        let p = e.plan_p2p(OpKind::Put, true, Locality::SameNode, 8 << 20, 1);
        assert_eq!(p.route, Route::CopyEngine);
        assert!(p.stripe_width >= 2, "no striping: {p:?}");
        assert!(p.chunks() >= p.stripe_width, "{p:?}");
        assert!(p.chunk_bytes <= e.chunk_max_bytes, "{p:?}");
        // Small transfers ship as one unit.
        let s = e.plan_p2p(OpKind::Put, true, Locality::SameNode, 4096, 1);
        assert_eq!((s.chunk_bytes, s.stripe_width, s.chunks()), (4096, 1, 1));
        // Load/store plans never stripe.
        let e = engine(CutoverConfig::never());
        let p = e.plan_p2p(OpKind::Put, true, Locality::SameNode, 8 << 20, 1);
        assert_eq!(p.stripe_width, 1);
        assert_eq!(p.chunks(), 1);
    }

    #[test]
    fn remote_plans_stripe_across_rails() {
        let e = engine(CutoverConfig::tuned());
        let p = e.plan_p2p(OpKind::Put, false, Locality::Remote, 8 << 20, 1);
        assert_eq!(p.route, Route::Nic);
        assert!(p.stripe_width >= 2, "no rail striping: {p:?}");
        assert!(p.chunk_bytes <= e.chunk_max_bytes, "{p:?}");
        assert!(p.chunks() >= p.stripe_width, "{p:?}");
        assert!(p.bucket().rails_pow2 >= 1, "{:?}", p.bucket());
        // Small remote transfers ship as one RDMA, in the width-1 bucket.
        let s = e.plan_p2p(OpKind::Put, false, Locality::Remote, 4096, 1);
        assert_eq!((s.chunk_bytes, s.stripe_width, s.chunks()), (4096, 1, 1));
        assert_eq!(s.bucket().rails_pow2, 0);
        assert_eq!(s.modeled_ns, e.cost.internode_ns(4096, true, true));
    }

    #[test]
    fn adaptive_table_json_roundtrips() {
        let a = engine(CutoverConfig::adaptive());
        for bytes in [4096usize, 1 << 20] {
            for items in [1usize, 128] {
                let p = a.plan_p2p(OpKind::Put, true, Locality::SameNode, bytes, items);
                a.record(&p, p.modeled_ns * 1.1);
            }
        }
        let sa = a.adaptive_snapshot();
        assert!(sa.len() >= 4, "warmup learned too little: {sa:?}");
        let b = engine(CutoverConfig::adaptive());
        let n = b.adaptive_load_json(&a.adaptive_save_json()).unwrap();
        assert_eq!(n, sa.len());
        let sb = b.adaptive_snapshot();
        assert_eq!(sa.len(), sb.len());
        for (x, y) in sa.iter().zip(&sb) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.samples_loadstore, y.samples_loadstore);
            assert_eq!(x.samples_copy_engine, y.samples_copy_engine);
            let close = |p: f64, q: f64| (p - q).abs() <= 1e-9 * p.abs().max(1.0);
            assert!(close(x.ema_loadstore_ns, y.ema_loadstore_ns), "{x:?} vs {y:?}");
            assert!(close(x.ema_copy_engine_ns, y.ema_copy_engine_ns), "{x:?} vs {y:?}");
        }
        // Garbage rejects cleanly.
        assert!(b.adaptive_load_json("{not json").is_err());
        assert!(b.adaptive_load_json("{\"cells\": 5}").is_err());
    }

    #[test]
    fn per_op_cl_policy_switches_startup_constant() {
        let e = engine(CutoverConfig::tuned());
        let loc = Locality::SameNode;
        let all_imm = e.est_copy_engine_ns(loc, 1 << 20);
        e.set_cl_immediate_max_bytes(64 << 10);
        assert_eq!(e.cl_immediate_max_bytes(), 64 << 10);
        assert_eq!(e.cost.model.version(), 0, "re-seeding the boundary is not a calibration");
        let std_cl = e.est_copy_engine_ns(loc, 1 << 20);
        let small = e.est_copy_engine_ns(loc, 4 << 10);
        assert!(std_cl > all_imm, "standard CL must charge the larger startup");
        assert!(e.cl_immediate_for(4 << 10) && !e.cl_immediate_for(1 << 20));
        assert_eq!(small, e.cost.p2p_engine_estimate_ns(loc, 4 << 10, true));
    }

    #[test]
    fn plans_are_stamped_with_the_model_version() {
        let e = engine(CutoverConfig::tuned());
        let p = e.plan_p2p(OpKind::Put, true, Locality::SameNode, 4096, 1);
        assert_eq!(p.model_version, 0);
        let r = e.plan_p2p(OpKind::Put, false, Locality::Remote, 4096, 1);
        assert_eq!(r.model_version, 0);
        e.cost.model.update(|l| l.single_engine_frac = 0.5);
        let p = e.plan_p2p(OpKind::Put, true, Locality::SameNode, 4096, 1);
        assert_eq!(p.model_version, 1);
        let f = e.plan_fanout(&FanoutShape::default(), 4096, 1);
        assert_eq!(f.model_version, 1);
    }

    #[test]
    fn recalibration_ages_out_learned_adaptive_cells() {
        let e = engine(CutoverConfig::adaptive());
        let (loc, bytes) = (Locality::SameNode, 4096);
        // Warm a cell and poison it so the learned choice diverges from
        // the seed choice.
        let seed_route = e.plan_p2p(OpKind::Put, true, loc, bytes, 1).route;
        assert_eq!(seed_route, Route::LoadStore, "4KiB single-item seeds load/store");
        let p = e.plan_p2p(OpKind::Put, true, loc, bytes, 1);
        for _ in 0..32 {
            e.record(&p, 1e9); // "observed" load/store catastrophically slow
        }
        let poisoned = e.plan_p2p(OpKind::Put, true, loc, bytes, 1);
        assert_eq!(poisoned.route, Route::CopyEngine, "poisoning must flip the cell");
        // A recalibration bumps the model version; the stale cell re-seeds
        // from the fresh estimates and the poison is gone.
        e.cost.model.update(|l| l.startup_standard_ns += 1.0);
        let fresh = e.plan_p2p(OpKind::Put, true, loc, bytes, 1);
        assert_eq!(fresh.route, Route::LoadStore, "stale cell must re-seed");
        let cell = e
            .adaptive_snapshot()
            .into_iter()
            .find(|c| c.key == fresh.bucket())
            .expect("cell exists");
        assert_eq!(cell.model_version, 1);
        assert_eq!(cell.samples_loadstore, 0, "re-seed resets samples");
    }

    #[test]
    fn persisted_table_with_mismatched_model_params_is_discarded() {
        use crate::util::json::Json;
        let a = engine(CutoverConfig::adaptive());
        let p = a.plan_p2p(OpKind::Put, true, Locality::SameNode, 4096, 1);
        a.record(&p, p.modeled_ns * 1.1);
        let saved = a.adaptive_save_json();
        // A fresh machine with the same (seed) params: loads — this is
        // the cross-process case, where version counters restart at 0 but
        // the fingerprint still matches.
        let b = engine(CutoverConfig::adaptive());
        assert!(b.adaptive_load_json(&saved).unwrap() >= 1);
        // A loader that recalibrated since: the saved cells were learned
        // under different hardware constants — discarded, not trusted.
        let c = engine(CutoverConfig::adaptive());
        c.cost.model.update(|l| l.single_engine_frac = 0.5);
        assert_eq!(c.adaptive_load_json(&saved).unwrap(), 0);
        assert!(c.adaptive_snapshot().is_empty());
        // The reverse cross-process case: a table saved by the calibrated
        // machine never fools a fresh (seed-params) process.
        let saved_calibrated = c.adaptive_save_json();
        let d = engine(CutoverConfig::adaptive());
        assert_eq!(d.adaptive_load_json(&saved_calibrated).unwrap(), 0);
        // A pre-calibration-era table (no fingerprint) is trusted only by
        // a machine still at its seed params.
        let mut obj = match Json::parse(&saved).unwrap() {
            Json::Obj(m) => m,
            other => panic!("table is not an object: {other:?}"),
        };
        obj.remove("model_params").expect("fingerprint present in saves");
        obj.remove("model_version");
        let legacy = Json::Obj(obj).to_string();
        assert!(b.adaptive_load_json(&legacy).unwrap() >= 1);
        assert_eq!(c.adaptive_load_json(&legacy).unwrap(), 0);
        // The saver stamps its (informational) local version too.
        assert!(saved_calibrated.contains("\"model_version\":1"), "{saved_calibrated}");
    }

    #[test]
    fn fanout_plan_scales_with_shape() {
        let e = engine(CutoverConfig::tuned());
        let shape = FanoutShape {
            per_link: vec![(Locality::SameNode, 4 << 20, 1), (Locality::SameNode, 4 << 20, 1)],
            nic_bytes: 0,
            npeers: 2,
            loc: Locality::SameNode,
        };
        // Huge per-peer payload with one work-item: engines must win.
        let p = e.plan_fanout(&shape, 4 << 20, 1);
        assert_eq!(p.route, Route::CopyEngine);
        // Empty fan-out costs nothing.
        let empty = FanoutShape::default();
        assert_eq!(e.fanout_store_ns(&empty, 4), 0.0);
        assert_eq!(e.fanout_engine_ns(&empty), 0.0);
    }

    // ------------------------------------------------- plan-cache tests --

    fn engine_with_cache(cfg: CutoverConfig, cache: PlanCacheConfig) -> XferEngine {
        let mut e = engine(cfg);
        e.set_plan_cache(cache);
        e
    }

    /// Every (route, locality, size, items) worth sweeping in the drift
    /// properties: reachable shapes across all intra-node localities plus
    /// unreachable (NIC) shapes, sizes straddling every cutover and
    /// striping regime.
    fn sweep_shapes() -> Vec<(bool, Locality, usize, usize)> {
        let mut v = Vec::new();
        for &bytes in &[8usize, 512, 4096, 64 << 10, 1 << 20, 8 << 20] {
            for &items in &[1usize, 16, 1024] {
                for &loc in &[Locality::SameTile, Locality::SameGpu, Locality::SameNode] {
                    v.push((true, loc, bytes, items));
                }
                v.push((false, Locality::Remote, bytes, items));
            }
        }
        v
    }

    fn sweep(e: &XferEngine) -> Vec<TransferPlan> {
        sweep_shapes()
            .iter()
            .map(|&(reach, loc, bytes, items)| {
                e.plan_p2p(OpKind::Put, reach, loc, bytes, items)
            })
            .collect()
    }

    #[test]
    fn cache_warm_plans_are_bit_identical_to_cache_off() {
        let cached = engine(CutoverConfig::tuned()); // cache on by default
        let off = engine_with_cache(
            CutoverConfig::tuned(),
            PlanCacheConfig { enable: false, capacity: 4096 },
        );
        let cold = sweep(&cached); // fills the cache
        let warm = sweep(&cached); // pure hits
        let reference = sweep(&off);
        assert_eq!(cold, reference, "cold cached sweep drifted from cache-off");
        assert_eq!(warm, reference, "warm cached sweep drifted from cache-off");
        let n = sweep_shapes().len() as u64;
        assert_eq!(cached.metrics.plan_cache_misses.load(Ordering::Relaxed), n);
        assert_eq!(cached.metrics.plan_cache_hits.load(Ordering::Relaxed), n);
        assert_eq!(cached.plan_cache_len(), n as usize);
        // The disabled cache neither stores nor counts.
        assert_eq!(off.plan_cache_len(), 0);
        assert_eq!(off.metrics.plan_cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(off.metrics.plan_cache_misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn version_bump_and_boundary_flip_never_serve_stale_plans() {
        let calibrate = |e: &XferEngine| {
            e.cost.model.update(|l| {
                l.single_engine_frac = 0.5;
                l.rail_bw_frac = 0.5;
                l.startup_standard_ns = 9_000.0;
            })
        };
        let cached = engine(CutoverConfig::tuned());
        let _ = sweep(&cached); // fill under the seed generation
        calibrate(&cached);
        // A cache-off oracle that only ever saw the calibrated params.
        let oracle = engine_with_cache(
            CutoverConfig::tuned(),
            PlanCacheConfig { enable: false, capacity: 4096 },
        );
        calibrate(&oracle);
        let post = sweep(&cached);
        assert_eq!(post, sweep(&oracle), "post-calibration sweep served stale plans");
        assert!(post.iter().all(|p| p.model_version == 1));
        // The version bump flushed the whole seed-generation population.
        assert!(
            cached.metrics.plan_cache_invalidations.load(Ordering::Relaxed)
                >= sweep_shapes().len() as u64
        );
        // The CL boundary can move *without* a version bump
        // (`seed_cl_boundary`) — the cache must still notice.
        let inval_before = cached.metrics.plan_cache_invalidations.load(Ordering::Relaxed);
        let _ = sweep(&cached); // re-fill at version 1
        cached.set_cl_immediate_max_bytes(64 << 10);
        oracle.set_cl_immediate_max_bytes(64 << 10);
        assert_eq!(cached.cost.model.version(), 1, "boundary re-seed is not a calibration");
        let post = sweep(&cached);
        assert_eq!(post, sweep(&oracle), "boundary flip served stale plans");
        assert!(
            cached.metrics.plan_cache_invalidations.load(Ordering::Relaxed) > inval_before
        );
    }

    #[test]
    fn health_bump_never_serves_stale_plans() {
        let cached = engine(CutoverConfig::tuned());
        let baseline = sweep(&cached); // fill under full health
        // Kill a rail (4 → 3 live) and enough engines to pull the stripe
        // cap down (8 → 3 live): remote and engine-path shapes must
        // re-price against the survivors, not the cached healthy widths.
        let kill = |e: &XferEngine| {
            assert!(e.cost.kill_rail(0, 1));
            for eng in 3..8 {
                assert!(e.cost.kill_engine(0, eng));
            }
        };
        kill(&cached);
        let oracle = engine_with_cache(
            CutoverConfig::tuned(),
            PlanCacheConfig { enable: false, capacity: 4096 },
        );
        kill(&oracle);
        let degraded = sweep(&cached);
        assert_eq!(degraded, sweep(&oracle), "health bump served stale plans");
        assert_ne!(degraded, baseline, "kills must actually re-stripe the big plans");
        assert!(
            cached.metrics.plan_cache_invalidations.load(Ordering::Relaxed)
                >= sweep_shapes().len() as u64
        );
        // Revival is a health transition too: the cache flushes again and
        // the healed sweep is bit-identical to the pre-kill baseline.
        assert!(cached.cost.revive_rail(0, 1));
        for eng in 3..8 {
            assert!(cached.cost.revive_engine(0, eng));
        }
        let healed = sweep(&cached);
        assert_eq!(healed, baseline, "revival did not restore the healthy plans");
    }

    #[test]
    fn last_lane_death_falls_back_and_counts() {
        let e = engine(CutoverConfig::always());
        // Kill every engine on GPU 0: even an `always` cutover must shed
        // to the raw-pointer load/store path instead of planning onto a
        // dead queue — counted, not panicked.
        for eng in 0..e.cost.params.ce.engines_per_gpu {
            assert!(e.cost.kill_engine(0, eng));
        }
        let p = e.plan_p2p_from(Some(0), OpKind::Put, true, Locality::SameNode, 8 << 20, 1);
        assert_eq!(p.route, Route::LoadStore);
        assert_eq!(e.metrics.fault_last_lane_fallbacks.load(Ordering::Relaxed), 1);
        // A GPU with live engines keeps the engine route, no fallback.
        let q = e.plan_p2p_from(Some(1), OpKind::Put, true, Locality::SameNode, 8 << 20, 1);
        assert_eq!(q.route, Route::CopyEngine);
        assert_eq!(e.metrics.fault_last_lane_fallbacks.load(Ordering::Relaxed), 1);
        // Kill every rail on the node: unreachable peers still plan — a
        // degenerate width-1 NIC route — and the fallback is counted.
        for rail in 0..e.cost.params.nic.rails {
            assert!(e.cost.kill_rail(0, rail));
        }
        let r = e.plan_p2p_from(Some(0), OpKind::Put, false, Locality::Remote, 8 << 20, 1);
        assert_eq!(r.route, Route::Nic);
        assert_eq!(r.stripe_width, 1);
        assert_eq!(e.metrics.fault_last_lane_fallbacks.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn adaptive_flips_apply_even_on_cache_hits() {
        let e = engine(CutoverConfig::adaptive());
        let (loc, bytes) = (Locality::SameNode, 4096);
        let p1 = e.plan_p2p(OpKind::Put, true, loc, bytes, 1);
        assert_eq!(p1.route, Route::LoadStore, "4KiB single-item seeds load/store");
        let p2 = e.plan_p2p(OpKind::Put, true, loc, bytes, 1); // cache hit
        assert_eq!(p2.route, Route::LoadStore);
        // Poison the cell: the learned route flips while the cached
        // structural shape stays valid.
        for _ in 0..32 {
            e.record(&p2, 1e9);
        }
        let p3 = e.plan_p2p(OpKind::Put, true, loc, bytes, 1); // still a hit
        assert_eq!(
            p3.route,
            Route::CopyEngine,
            "cache hit served the pre-flip adaptive decision"
        );
        // All three post-fill plans really were hits — the decision is
        // outside the cached portion, not cached-and-invalidated.
        assert_eq!(e.metrics.plan_cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(e.metrics.plan_cache_misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fanout_plans_memoize_by_layout_digest() {
        let e = engine(CutoverConfig::tuned());
        let shape = FanoutShape {
            per_link: vec![(Locality::SameNode, 1 << 20, 2), (Locality::SameGpu, 512 << 10, 1)],
            nic_bytes: 64 << 10,
            npeers: 3,
            loc: Locality::SameNode,
        };
        let off = engine_with_cache(
            CutoverConfig::tuned(),
            PlanCacheConfig { enable: false, capacity: 4096 },
        );
        let cold = e.plan_fanout(&shape, 512 << 10, 16);
        let warm = e.plan_fanout(&shape, 512 << 10, 16);
        let reference = off.plan_fanout(&shape, 512 << 10, 16);
        assert_eq!(cold, reference, "cold fan-out plan drifted from cache-off");
        assert_eq!(warm, reference, "warm fan-out plan drifted from cache-off");
        assert_eq!(e.metrics.plan_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(e.metrics.plan_cache_hits.load(Ordering::Relaxed), 1);
        // A different layout of the same (loc, bytes, items) is a distinct
        // entry, not a false hit.
        let other = FanoutShape {
            per_link: vec![(Locality::SameNode, 2 << 20, 3)],
            nic_bytes: 0,
            npeers: 3,
            loc: Locality::SameNode,
        };
        let p = e.plan_fanout(&other, 512 << 10, 16);
        assert_eq!(p, off.plan_fanout(&other, 512 << 10, 16));
        assert_eq!(e.metrics.plan_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(e.plan_cache_len(), 2);
        // Recalibration flushes fan-out entries like p2p ones.
        e.cost.model.update(|l| l.single_engine_frac = 0.5);
        off.cost.model.update(|l| l.single_engine_frac = 0.5);
        let post = e.plan_fanout(&shape, 512 << 10, 16);
        assert_eq!(post, off.plan_fanout(&shape, 512 << 10, 16));
        assert_eq!(post.model_version, 1);
    }

    #[test]
    fn coll_decide_selects_and_learns_per_team_size() {
        use crate::sim::cost::CollOp;
        // Non-adaptive modes: plain model argmin, no cells created.
        let t = engine(CutoverConfig::tuned());
        assert!(t.coll_decide(CollOp::Broadcast, 1 << 20, 64, 200.0, 100.0, 0));
        assert!(!t.coll_decide(CollOp::Broadcast, 1 << 20, 64, 100.0, 200.0, 0));
        assert!(!t.coll_decide(CollOp::Broadcast, 1 << 20, 64, 100.0, 100.0, 0), "ties → flat");
        assert!(t.adaptive_snapshot().is_empty());
        // Adaptive: the cell seeds from the estimates, then observations
        // of the hierarchical algorithm move only its own team size.
        let a = engine(CutoverConfig::adaptive());
        assert!(a.coll_decide(CollOp::Reduce, 1 << 20, 64, 200.0, 100.0, 0));
        assert!(a.coll_decide(CollOp::Reduce, 1 << 20, 256, 200.0, 100.0, 0));
        for _ in 0..32 {
            a.coll_observe(CollOp::Reduce, 1 << 20, 64, true, 1e9, 0);
        }
        assert!(!a.coll_decide(CollOp::Reduce, 1 << 20, 64, 200.0, 100.0, 0), "hier priced out");
        assert!(a.coll_decide(CollOp::Reduce, 1 << 20, 256, 200.0, 100.0, 0));
        assert!(a.metrics.adaptive_updates.load(Ordering::Relaxed) >= 32);
        // Collective cells persist with their class tag.
        let b = engine(CutoverConfig::adaptive());
        b.adaptive_load_json(&a.adaptive_save_json()).unwrap();
        assert!(!b.coll_decide(CollOp::Reduce, 1 << 20, 64, 200.0, 100.0, 0));
    }

    #[test]
    fn chain_estimates_save_round_trips_and_memoize() {
        let e = engine(CutoverConfig::tuned());
        let put = ChainStage { reachable: false, loc: Locality::Remote, bytes: 64 << 10 };
        let sig = ChainStage { reachable: false, loc: Locality::Remote, bytes: 8 };
        for depth in 2..=4usize {
            let stages: Vec<ChainStage> =
                std::iter::repeat(put).take(depth - 1).chain([sig]).collect();
            let fused = e.est_chain_ns(&stages);
            let seq = e.est_chain_sequential_ns(&stages);
            let rtt = e.cost.ring_rtt_ns();
            // Fusing saves exactly the d-1 extra round trips.
            assert!(
                (seq - fused - (depth as f64 - 1.0) * rtt).abs() < 1e-6,
                "depth {depth}: fused {fused} vs seq {seq} (rtt {rtt})"
            );
            assert!(e.chain_fuse_wins(&stages), "depth {depth} must fuse");
        }
        // Warm calls are cache hits that reproduce the cold estimates.
        let stages = [put, put, sig];
        let cold = e.est_chain_ns(&stages);
        let hits = e.metrics.plan_cache_hits.load(Ordering::Relaxed);
        assert_eq!(e.est_chain_ns(&stages), cold);
        assert!(e.metrics.plan_cache_hits.load(Ordering::Relaxed) > hits);
        // Mixed local/remote chains price each stage at its own route.
        let local = ChainStage { reachable: true, loc: Locality::SameNode, bytes: 1 << 20 };
        let mixed = [local, sig];
        assert!(e.est_chain_ns(&mixed) < e.est_chain_sequential_ns(&mixed));
    }

    #[test]
    fn strike_notes_flush_cached_plans() {
        let cached = engine(CutoverConfig::tuned());
        let baseline = sweep(&cached); // fill under a strike-free ledger
        cached.cost.note_rail_strike(0, 1);
        let oracle = engine_with_cache(
            CutoverConfig::tuned(),
            PlanCacheConfig { enable: false, capacity: 4096 },
        );
        oracle.cost.note_rail_strike(0, 1);
        let struck = sweep(&cached);
        assert_eq!(struck, sweep(&oracle), "strike bump served stale plans");
        // Forgiving the lane restores the strike-free plans bit-for-bit.
        cached.cost.clear_rail_strikes(0, 1);
        assert_eq!(sweep(&cached), baseline, "forgiveness did not restore plans");
    }

    #[test]
    fn concurrent_recalibration_never_tears_plans() {
        use crate::sim::params::LearnedParams;
        use std::sync::atomic::AtomicBool;
        fn set_a(l: &mut LearnedParams) {
            l.single_engine_frac = 0.25;
            l.rail_bw_frac = 0.8;
            l.startup_standard_ns = 8_000.0;
        }
        fn set_b(l: &mut LearnedParams) {
            l.single_engine_frac = 0.5;
            l.rail_bw_frac = 0.4;
            l.startup_standard_ns = 16_000.0;
        }
        let shapes = sweep_shapes();
        // Oracle engine-side / NIC-side estimates under each param set:
        // a torn plan (terms priced under a mix of generations) matches
        // neither bitwise.
        let oracle = |setter: &dyn Fn(&mut LearnedParams)| -> Vec<f64> {
            let o = engine_with_cache(
                CutoverConfig::tuned(),
                PlanCacheConfig { enable: false, capacity: 4096 },
            );
            o.cost.model.update(setter);
            shapes
                .iter()
                .map(|&(reach, loc, bytes, _)| {
                    if reach {
                        o.est_copy_engine_ns(loc, bytes)
                    } else {
                        o.est_nic_ns(bytes)
                    }
                })
                .collect()
        };
        let exp_a = oracle(&set_a);
        let exp_b = oracle(&set_b);
        let e = engine(CutoverConfig::tuned());
        e.cost.model.update(set_a); // start in a known generation
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..200 {
                    e.cost.model.update(if i % 2 == 0 { set_b } else { set_a });
                }
                stop.store(true, Ordering::Relaxed);
            });
            for _ in 0..3 {
                s.spawn(|| {
                    while !stop.load(Ordering::Relaxed) {
                        for (i, &(reach, loc, bytes, items)) in shapes.iter().enumerate() {
                            let p = e.plan_p2p(OpKind::Put, reach, loc, bytes, items);
                            let got = match p.route {
                                Route::CopyEngine | Route::Nic => p.modeled_ns,
                                Route::LoadStore => p.alt_ns.unwrap(),
                            };
                            assert!(
                                got == exp_a[i] || got == exp_b[i],
                                "torn plan at {loc:?}/{bytes}B/{items}wi: \
                                 {got} matches neither {} nor {}",
                                exp_a[i],
                                exp_b[i],
                            );
                        }
                    }
                });
            }
        });
    }
}
