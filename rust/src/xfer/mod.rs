//! The unified transfer-plan engine — one owner for the whole
//! device-initiated data path (paper §III-B/C/D, §IV).
//!
//! Before this subsystem existed, the plan→execute→complete flow was
//! duplicated per API family: `ishmem/cutover.rs` decided point-to-point
//! paths, `ishmem/collectives.rs` re-derived the same mode/threshold
//! branching for fan-outs, and each of `rma.rs`/`amo.rs`/`signal.rs`
//! composed its own ring messages and charged the cost model by hand. Now
//! every device-initiated operation flows through exactly one pipeline:
//!
//! 1. **Plan** ([`plan::XferEngine`]) — classify the request (op kind,
//!    locality, bytes, cooperating work-items), model the candidate paths,
//!    and pick a [`plan::Route`]:
//!    * `LoadStore` — organic GPU load/store over Xe-Link (§III-B),
//!    * `CopyEngine` — reverse offload → host proxy → blitter engines
//!      (§III-C, Fig 2 circle 3),
//!    * `Nic` — inter-node proxy → OFI transport (§III-D).
//!    The decision honours [`crate::ishmem::CutoverMode`]: `Never`/`Always`
//!    pin a path (the artifact's evaluation patches), `Tuned` is the
//!    shipping model-argmin policy (§IV, Fig 5–7), and `Adaptive` learns
//!    per-(locality, size-bucket, work-items-bucket) thresholds online
//!    ([`adaptive::AdaptiveTable`]): seeded from the `Tuned` model,
//!    refined by exponential moving averages of observed costs.
//! 2. **Execute** ([`exec`]) — one executor per route. Proxied routes no
//!    longer pay one ring message per op: executors append descriptors to
//!    the per-initiator command stream ([`stream::CmdStream`]), payloads
//!    are staged through the symmetric-heap staging slab, and one
//!    `RingOp::Batch` doorbell submits the whole plan-group (descriptor
//!    wire format in [`crate::ringbuf::batch`]). The raw-pointer
//!    one-message-per-op path survives only as the oversized-payload
//!    fallback. Dependent-operation *chains* (ISSUE 10, `chain.enable`)
//!    fuse put→signal / signal-gate→get sequences into one stage-stamped
//!    doorbell the proxy dispatches trigger-by-trigger
//!    ([`stream`]::`stream_post_chain`, priced by
//!    [`plan::XferEngine::chain_fuse_wins`]).
//! 3. **Complete** ([`track::CompletionTracker`]) — unified blocking/NBI
//!    completion state per PE: the modeled completion horizon of
//!    outstanding non-blocking transfers plus the count of fire-and-forget
//!    proxied messages that `ishmem_quiet` must flush.
//!
//! Paper map: plan ↔ §III-B cutover tuning + Fig 5 crossovers; execute ↔
//! §III-C command lists / §III-D ring + proxy; complete ↔ §9.11 ordering
//! semantics (`fence`/`quiet`). Fig 5's tuned crossover can be compared
//! against the learned table through
//! [`plan::XferEngine::adaptive_report`] and the `fig5_cutover` bench.
//!
//! A fourth stage closes the loop behind all three: **calibrate**
//! ([`calibrate::Calibrator`]) consumes the proxy's per-(path, lane,
//! size-class) wall-time observations and EMA-refines the learnable
//! hardware constants in the shared, versioned
//! [`crate::sim::params::ModelParams`] store — so plans, adaptive cells,
//! and the per-op CL policy all re-score against *observed* hardware
//! behavior (`calib.*` knobs; `rishmem figure calibration`).

pub mod adaptive;
pub mod calibrate;
pub mod exec;
pub mod plan;
pub mod stream;
pub mod track;

pub use adaptive::{AdaptiveCell, AdaptiveTable, BucketKey};
pub use calibrate::{CalibConfig, CalibrationSnapshot, Calibrator};
pub use plan::{ChainStage, FanoutShape, OpKind, PlanCacheConfig, Route, TransferPlan, XferEngine};
pub use stream::CmdStream;
pub use track::CompletionTracker;
