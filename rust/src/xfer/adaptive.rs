//! Online-adaptive cutover state (`CutoverMode::Adaptive`).
//!
//! The `Tuned` policy (paper §IV) picks the path whose *first-order model*
//! is cheaper. `Adaptive` keeps that model as the seed but learns from the
//! transfers it actually executes: per (locality, size-bucket,
//! work-items-bucket) cell it maintains an exponential moving average of
//! the observed cost of each path and picks the argmin of the EMAs. Cells
//! are seeded with the model estimates on first touch, so cold decisions
//! equal `Tuned` and warm decisions converge back to `Tuned` whenever the
//! model matches reality — while drifting hardware (or a mis-calibrated
//! model) moves the learned crossover without a re-tune.
//!
//! Buckets are power-of-two: sizes and work-item counts are binned by
//! `log2`, mirroring how the paper sweeps both axes (Figs 4–6).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::ishmem::cutover::Path;
use crate::sim::cost::CollOp;
use crate::sim::topology::Locality;
use crate::util::hash::{fast_hash, FastState};
use crate::util::rng::AtomicRng;

/// One learned-threshold cell key: (locality, log2 size, log2 items),
/// split by op class — fan-out observations measure a whole one-to-many
/// push and must not poison the point-to-point cells of the same size.
/// Fan-out cells additionally carry a log2 peer-count bucket: the whole-
/// push cost scales with the fan-out width (paper Fig 6's third axis),
/// so differently-sized fan-outs must not alias into one EMA.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub loc: Locality,
    pub size_pow2: u8,
    pub items_pow2: u8,
    /// true = collective fan-out cell, false = point-to-point cell.
    pub fanout: bool,
    /// log2 destination-peer bucket (0 for point-to-point).
    pub peers_pow2: u8,
    /// log2 NIC-rail-width bucket of remote cells (0 intra-node): a
    /// 4-rail-striped remote observation must not alias the single-rail
    /// cell of the same size.
    pub rails_pow2: u8,
    /// Collective algorithm-selection class: 0 for transfer cells,
    /// `1 + CollOp` for collective cells (broadcast/fcollect/reduce keep
    /// separate crossovers). In a collective cell the two path slots hold
    /// *algorithms* — slot 0 (LoadStore) the flat fan-out, slot 1
    /// (CopyEngine) the best hierarchical variant — and `peers_pow2`
    /// carries the team-size bucket (the crossover moves with team size).
    pub coll_op: u8,
}

impl BucketKey {
    /// Point-to-point cell (put/get/put-signal).
    pub fn p2p(loc: Locality, bytes: usize, items: usize) -> Self {
        BucketKey {
            loc,
            size_pow2: log2_bucket(bytes),
            items_pow2: log2_bucket(items),
            fanout: false,
            peers_pow2: 0,
            rails_pow2: 0,
            coll_op: 0,
        }
    }

    /// Collective fan-out cell (per-peer byte size, destination count).
    pub fn fanout(loc: Locality, bytes: usize, items: usize, npeers: usize) -> Self {
        BucketKey {
            fanout: true,
            peers_pow2: log2_bucket(npeers),
            ..Self::p2p(loc, bytes, items)
        }
    }

    /// Remote point-to-point cell: the rail width the transfer striped
    /// across is its own bucket dimension.
    pub fn remote(bytes: usize, items: usize, rail_width: usize) -> Self {
        BucketKey {
            rails_pow2: log2_bucket(rail_width),
            ..Self::p2p(Locality::Remote, bytes, items)
        }
    }

    /// Collective algorithm-selection cell (per-PE payload bytes, team
    /// size): the adaptive-cutover table's team-size bucket dimension.
    /// Slot 0 prices the flat fan-out, slot 1 the best hierarchical
    /// variant; calibration feedback re-seeds these cells exactly like
    /// transfer cells, so algorithm choice tracks the learned model.
    pub fn coll(op: CollOp, bytes: usize, team_size: usize) -> Self {
        BucketKey {
            loc: Locality::Remote,
            size_pow2: log2_bucket(bytes),
            items_pow2: 0,
            fanout: false,
            peers_pow2: log2_bucket(team_size),
            rails_pow2: 0,
            coll_op: 1 + op as u8,
        }
    }
}

/// Power-of-two bucket index of `v` (0 for 0/1).
fn log2_bucket(v: usize) -> u8 {
    if v <= 1 {
        0
    } else {
        (usize::BITS - 1 - v.leading_zeros()) as u8
    }
}

#[derive(Clone, Copy, Debug)]
struct CellState {
    /// EMA cost estimate per path: [LoadStore, CopyEngine], ns.
    ema_ns: [f64; 2],
    /// Observation count per path.
    samples: [u64; 2],
    /// `ModelParams` version this cell's seed (and every observation
    /// since) was taken under. A recalibration bumps the live version, so
    /// the next decision on a stale cell re-seeds it from the *current*
    /// model estimates instead of trusting EMAs learned against the old
    /// hardware model — the ROADMAP's "age out stale cells" item.
    model_version: u64,
}

fn path_index(path: Path) -> usize {
    match path {
        Path::LoadStore => 0,
        Path::CopyEngine => 1,
    }
}

/// The one argmin rule every reader of a cell applies (ties → LoadStore,
/// matching the `Tuned` policy). Changing tie-breaks or adding hysteresis
/// happens here and nowhere else.
pub(crate) fn argmin_path(loadstore_ns: f64, copy_engine_ns: f64) -> Path {
    if loadstore_ns <= copy_engine_ns {
        Path::LoadStore
    } else {
        Path::CopyEngine
    }
}

/// A snapshot row of the learned table (reports / benches).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveCell {
    pub key: BucketKey,
    pub ema_loadstore_ns: f64,
    pub ema_copy_engine_ns: f64,
    pub samples_loadstore: u64,
    pub samples_copy_engine: u64,
    /// `ModelParams` version the cell was seeded under (staleness token).
    pub model_version: u64,
}

impl AdaptiveCell {
    pub fn choice(&self) -> Path {
        argmin_path(self.ema_loadstore_ns, self.ema_copy_engine_ns)
    }
}

/// How many independent cell shards the table splits into: concurrent
/// planners touching different buckets lock different shards, so the
/// issue path never funnels every decision through one global `Mutex`.
const SHARDS: usize = 8;

/// Learned per-bucket path costs, shared by every PE of a machine.
///
/// The cell map is sharded by key hash and the ε-exploration stream is a
/// lock-free [`AtomicRng`], so concurrent planners only contend when they
/// hash into the same shard — the table never serializes the whole issue
/// path the way the former single `Mutex<HashMap>` + `Mutex<Rng>` pair
/// did.
#[derive(Debug)]
pub struct AdaptiveTable {
    shards: Vec<Mutex<HashMap<BucketKey, CellState, FastState>>>,
    /// EMA weight of a new observation (0 < alpha ≤ 1).
    alpha: f64,
    /// ε-exploration rate: with probability `eps` a decision takes the
    /// *losing* path so its EMA keeps seeing fresh observations. Without
    /// it a mis-seeded cell can never recover the path it stopped trying
    /// (0 = greedy, the default).
    eps: f64,
    /// Deterministic exploration stream (fixed seed — single-threaded
    /// decisions replay the exact pre-sharding `Mutex<Rng>` sequence).
    rng: AtomicRng,
}

impl AdaptiveTable {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "EMA alpha out of (0, 1]");
        AdaptiveTable {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::with_hasher(FastState))).collect(),
            alpha,
            eps: 0.0,
            rng: AtomicRng::new(0xADA9_71CE),
        }
    }

    /// The shard holding `key`'s cell.
    #[inline]
    fn shard(&self, key: &BucketKey) -> &Mutex<HashMap<BucketKey, CellState, FastState>> {
        &self.shards[(fast_hash(key) as usize) % SHARDS]
    }

    /// Enable ε-exploration (clamped to [0, 0.5]; 0 disables it).
    pub fn with_exploration(mut self, eps: f64) -> Self {
        self.eps = eps.clamp(0.0, 0.5);
        self
    }

    /// Decide a path for `key`, seeding the cell from the model estimates
    /// (`seed_loadstore_ns`, `seed_copy_engine_ns`) on first touch. With
    /// ε-exploration enabled, an occasional decision deliberately takes
    /// the losing path (its observation then refreshes that path's EMA —
    /// how a poisoned seed recovers).
    ///
    /// `model_version` is the live `ModelParams` version the seeds were
    /// computed under: a cell seeded under an older version is **stale**
    /// (its EMAs mix observations priced against a hardware model that no
    /// longer exists) and is re-seeded from the fresh estimates before
    /// deciding — recalibration ages the learned table out cell-by-cell
    /// as traffic touches it. Callers without a versioned model pass 0
    /// (the never-recalibrated version).
    pub fn decide(
        &self,
        key: BucketKey,
        seed_loadstore_ns: f64,
        seed_copy_engine_ns: f64,
        model_version: u64,
    ) -> Path {
        let greedy = {
            let mut cells = self.shard(&key).lock().unwrap();
            let cell = cells.entry(key).or_insert(CellState {
                ema_ns: [seed_loadstore_ns, seed_copy_engine_ns],
                samples: [0, 0],
                model_version,
            });
            if cell.model_version != model_version {
                *cell = CellState {
                    ema_ns: [seed_loadstore_ns, seed_copy_engine_ns],
                    samples: [0, 0],
                    model_version,
                };
            }
            argmin_path(cell.ema_ns[0], cell.ema_ns[1])
        };
        if self.eps > 0.0 && self.rng.f64() < self.eps {
            return match greedy {
                Path::LoadStore => Path::CopyEngine,
                Path::CopyEngine => Path::LoadStore,
            };
        }
        greedy
    }

    /// Feed back the observed (modeled) cost of an executed transfer.
    /// Returns whether a cell was actually refined (observations for
    /// never-decided cells are dropped — there is no seed to refine).
    ///
    /// `model_version` is the version the *plan* was priced under
    /// (`TransferPlan::model_version`): an observation from a plan issued
    /// before a recalibration must not pollute a cell that has since been
    /// re-seeded for the new model — it is dropped instead.
    pub fn observe(&self, key: BucketKey, path: Path, observed_ns: f64, model_version: u64) -> bool {
        let mut cells = self.shard(&key).lock().unwrap();
        if let Some(cell) = cells.get_mut(&key) {
            if cell.model_version != model_version {
                return false;
            }
            let i = path_index(path);
            cell.ema_ns[i] = (1.0 - self.alpha) * cell.ema_ns[i] + self.alpha * observed_ns;
            cell.samples[i] += 1;
            true
        } else {
            false
        }
    }

    /// Read a cell's current choice without creating/seeding it.
    pub fn peek(&self, key: BucketKey) -> Option<Path> {
        let cells = self.shard(&key).lock().unwrap();
        cells.get(&key).map(|c| argmin_path(c.ema_ns[0], c.ema_ns[1]))
    }

    /// Number of learned cells.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the whole table, sorted by (class, loc, peers, rails,
    /// items, size).
    pub fn snapshot(&self) -> Vec<AdaptiveCell> {
        let mut v: Vec<AdaptiveCell> = Vec::new();
        for shard in &self.shards {
            let cells = shard.lock().unwrap();
            v.extend(cells.iter().map(|(k, c)| AdaptiveCell {
                key: *k,
                ema_loadstore_ns: c.ema_ns[0],
                ema_copy_engine_ns: c.ema_ns[1],
                samples_loadstore: c.samples[0],
                samples_copy_engine: c.samples[1],
                model_version: c.model_version,
            }));
        }
        v.sort_by_key(|c| {
            (
                c.key.coll_op,
                c.key.fanout,
                c.key.loc as u8,
                c.key.peers_pow2,
                c.key.rails_pow2,
                c.key.items_pow2,
                c.key.size_pow2,
            )
        });
        v
    }

    /// Install previously-learned cells (table persistence across runs):
    /// each imported cell replaces any existing cell with the same key,
    /// EMAs and sample counts included, so a loaded table decides exactly
    /// like the run that saved it.
    pub fn load_cells(&self, cells: &[AdaptiveCell]) {
        for c in cells {
            self.shard(&c.key).lock().unwrap().insert(
                c.key,
                CellState {
                    ema_ns: [c.ema_loadstore_ns, c.ema_copy_engine_ns],
                    samples: [c.samples_loadstore, c.samples_copy_engine],
                    model_version: c.model_version,
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2() {
        assert_eq!(log2_bucket(0), 0);
        assert_eq!(log2_bucket(1), 0);
        assert_eq!(log2_bucket(2), 1);
        assert_eq!(log2_bucket(3), 1);
        assert_eq!(log2_bucket(4096), 12);
        assert_eq!(log2_bucket(4097), 12);
    }

    #[test]
    fn seed_decides_like_argmin_then_ema_learns() {
        let t = AdaptiveTable::new(0.5);
        let k = BucketKey::p2p(Locality::SameNode, 4096, 16);
        // Seed says load/store is cheaper.
        assert_eq!(t.decide(k, 100.0, 200.0, 0), Path::LoadStore);
        // Observations say the store path is actually much slower.
        for _ in 0..16 {
            t.observe(k, Path::LoadStore, 1000.0, 0);
        }
        assert_eq!(t.peek(k), Some(Path::CopyEngine));
        // Re-seeding an existing cell does not reset what was learned.
        assert_eq!(t.decide(k, 100.0, 200.0, 0), Path::CopyEngine);
    }

    #[test]
    fn observe_without_cell_is_noop() {
        let t = AdaptiveTable::new(0.25);
        let k = BucketKey::p2p(Locality::SameGpu, 64, 1);
        assert!(!t.observe(k, Path::CopyEngine, 5.0, 0));
        assert_eq!(t.peek(k), None);
        assert!(t.is_empty());
    }

    #[test]
    fn exploration_occasionally_takes_the_losing_path() {
        let t = AdaptiveTable::new(0.5).with_exploration(0.25);
        let k = BucketKey::p2p(Locality::SameNode, 4096, 1);
        let mut explored = 0;
        for _ in 0..200 {
            if t.decide(k, 100.0, 200.0, 0) == Path::CopyEngine {
                explored += 1;
            }
        }
        // ~25% of 200 draws; deterministic RNG, loose bounds.
        assert!(explored > 20 && explored < 90, "explored {explored}/200");
        // Greedy tables never deviate.
        let g = AdaptiveTable::new(0.5);
        assert!((0..200).all(|_| g.decide(k, 100.0, 200.0, 0) == Path::LoadStore));
    }

    #[test]
    fn remote_cells_are_disjoint_by_rail_width() {
        let r1 = BucketKey::remote(1 << 20, 1, 1);
        let r4 = BucketKey::remote(1 << 20, 1, 4);
        assert_ne!(r1, r4);
        assert_eq!(r1, BucketKey::p2p(Locality::Remote, 1 << 20, 1));
        let t = AdaptiveTable::new(0.5);
        t.decide(r1, 100.0, 200.0, 0);
        t.decide(r4, 100.0, 200.0, 0);
        for _ in 0..16 {
            assert!(t.observe(r4, Path::LoadStore, 10_000.0, 0));
        }
        assert_eq!(t.peek(r1), Some(Path::LoadStore));
        assert_eq!(t.peek(r4), Some(Path::CopyEngine));
    }

    #[test]
    fn recalibration_reseeds_stale_cells_on_next_touch() {
        let t = AdaptiveTable::new(0.5);
        let k = BucketKey::p2p(Locality::SameNode, 4096, 16);
        // Learn something under model version 0 that flips the seed.
        assert_eq!(t.decide(k, 100.0, 200.0, 0), Path::LoadStore);
        for _ in 0..16 {
            t.observe(k, Path::LoadStore, 1000.0, 0);
        }
        assert_eq!(t.peek(k), Some(Path::CopyEngine));
        // Same version: the learned state stands.
        assert_eq!(t.decide(k, 100.0, 200.0, 0), Path::CopyEngine);
        // A recalibrated model (version 3) ages the cell out: fresh seeds
        // win, samples reset, and the cell carries the new version.
        assert_eq!(t.decide(k, 100.0, 200.0, 3), Path::LoadStore);
        let c = t.snapshot()[0];
        assert_eq!(c.model_version, 3);
        assert_eq!((c.samples_loadstore, c.samples_copy_engine), (0, 0));
        assert_eq!(c.ema_loadstore_ns, 100.0);
        // Untouched keys under the new version seed normally.
        let k2 = BucketKey::p2p(Locality::SameNode, 8192, 16);
        assert_eq!(t.decide(k2, 300.0, 200.0, 3), Path::CopyEngine);
    }

    #[test]
    fn loaded_cells_replace_and_decide_like_the_saver() {
        let a = AdaptiveTable::new(0.5);
        let k = BucketKey::p2p(Locality::SameNode, 4096, 16);
        a.decide(k, 100.0, 200.0, 0);
        for _ in 0..8 {
            a.observe(k, Path::LoadStore, 1000.0, 0);
        }
        let cells = a.snapshot();
        let b = AdaptiveTable::new(0.5);
        b.load_cells(&cells);
        assert_eq!(b.len(), a.len());
        assert_eq!(b.peek(k), a.peek(k));
        let bc = &b.snapshot()[0];
        let ac = &cells[0];
        assert_eq!(bc.samples_loadstore, ac.samples_loadstore);
        assert_eq!(bc.ema_loadstore_ns, ac.ema_loadstore_ns);
    }

    #[test]
    fn concurrent_planners_learn_without_losing_updates() {
        // 4 threads × 64 keys spread across the shards: every decide
        // seeds its cell and every observe lands — the sharded table is
        // a drop-in for the old globally-locked map.
        let t = AdaptiveTable::new(0.5).with_exploration(0.1);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..64usize {
                        let k = BucketKey::p2p(Locality::SameNode, 1 << (i % 16), i);
                        t.decide(k, 100.0, 200.0, 0);
                        assert!(t.observe(k, Path::LoadStore, 150.0, 0));
                    }
                });
            }
        });
        let cells = t.snapshot();
        assert_eq!(cells.len(), t.len());
        let total: u64 = cells.iter().map(|c| c.samples_loadstore).sum();
        assert_eq!(total, 4 * 64, "every concurrent observation landed");
    }

    #[test]
    fn coll_cells_are_disjoint_by_op_team_size_and_from_transfers() {
        let b64 = BucketKey::coll(CollOp::Broadcast, 1 << 20, 64);
        let b256 = BucketKey::coll(CollOp::Broadcast, 1 << 20, 256);
        let r64 = BucketKey::coll(CollOp::Reduce, 1 << 20, 64);
        assert_ne!(b64, b256, "team size is its own bucket dimension");
        assert_ne!(b64, r64, "ops keep separate crossovers");
        // Never collides with the transfer cells of the same geometry.
        assert_ne!(b64, BucketKey::p2p(Locality::Remote, 1 << 20, 1));
        assert_ne!(b64, BucketKey::fanout(Locality::Remote, 1 << 20, 0, 64));
        // Learning flat-vs-hier on one team size leaves others alone.
        let t = AdaptiveTable::new(0.5);
        t.decide(b64, 100.0, 200.0, 0);
        t.decide(b256, 100.0, 200.0, 0);
        for _ in 0..16 {
            assert!(t.observe(b64, Path::LoadStore, 10_000.0, 0));
        }
        assert_eq!(t.peek(b64), Some(Path::CopyEngine), "flat priced out");
        assert_eq!(t.peek(b256), Some(Path::LoadStore));
    }

    #[test]
    fn fanout_cells_are_disjoint_from_p2p_and_by_width() {
        let t = AdaptiveTable::new(0.5);
        let p2p = BucketKey::p2p(Locality::SameNode, 4096, 16);
        let fan2 = BucketKey::fanout(Locality::SameNode, 4096, 16, 2);
        let fan12 = BucketKey::fanout(Locality::SameNode, 4096, 16, 12);
        assert_ne!(p2p, fan2);
        assert_ne!(fan2, fan12);
        // A huge whole-push observation on the wide fan-out must not
        // flip the narrow fan-out's (or the p2p) decision.
        t.decide(p2p, 100.0, 200.0, 0);
        t.decide(fan2, 100.0, 200.0, 0);
        t.decide(fan12, 100.0, 200.0, 0);
        for _ in 0..16 {
            assert!(t.observe(fan12, Path::LoadStore, 10_000.0, 0));
        }
        assert_eq!(t.peek(p2p), Some(Path::LoadStore));
        assert_eq!(t.peek(fan2), Some(Path::LoadStore));
        assert_eq!(t.peek(fan12), Some(Path::CopyEngine));
    }
}
