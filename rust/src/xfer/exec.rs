//! Executors: carry out a [`TransferPlan`] (plan→**execute**→complete).
//!
//! One executor per [`Route`]:
//! * `LoadStore` — direct stores/loads into the peer heap (the real bytes
//!   move through the shared-memory substrate), charged at the Xe-Link
//!   work-item store rate (§III-B);
//! * `CopyEngine` — reverse offload: compose a 64-byte ring message
//!   (§III-D), block on the proxy's completion, charge ring RTT + engine
//!   time with queue-aware occupancy (§III-C);
//! * `Nic` — same ring hand-off, but the proxy forwards to the OFI
//!   transport (inter-node, §III-D).
//!
//! This module is also the **only** place that composes reverse-offload
//! ring messages for RMA/AMO/signal ops — the per-op copies that used to
//! live in `rma.rs`, `amo.rs` and `signal.rs` are gone. Executors feed
//! observed (modeled) durations back to the planner so
//! `CutoverMode::Adaptive` learns online.

use crate::coordinator::metrics::Metrics;
use crate::ishmem::PeCtx;
use crate::ringbuf::message::AmoKind;
use crate::ringbuf::{Message, RingOp, COMPLETION_NONE};
use crate::sim::topology::Locality;
use crate::sim::SimClock;

use super::plan::{OpKind, Route, TransferPlan};

/// Message flag: `src_off`/`dst_off` is a raw in-process pointer (the
/// initiator's private buffer), not a symmetric-heap offset.
pub(crate) const FLAG_RAW_PTR: u16 = 1 << 8;

/// Completion payloads for non-fetching proxied ops.
pub(crate) const PROXY_OK: u64 = 0;
pub(crate) const PROXY_ERR_UNREGISTERED: u64 = 1;

/// Compose a reverse-offload RMA ring message (the one wire format all
/// put/get/put-signal traffic shares).
pub(crate) fn rma_message(
    op: RingOp,
    pe: usize,
    dst_off: u64,
    src_off: u64,
    len: usize,
) -> Message {
    let mut m = Message::nop();
    m.op = op as u8;
    m.flags = FLAG_RAW_PTR;
    m.pe = pe as u32;
    m.dst_off = dst_off;
    m.src_off = src_off;
    m.len = len as u64;
    m
}

impl PeCtx {
    // ----------------------------------------------------------- planning --

    /// Plan a point-to-point transfer to `pe`: IPC-table reachability
    /// lookup (§III-G.1 step 2) + locality classification, then the
    /// engine's path decision.
    pub(crate) fn plan_to(&self, kind: OpKind, pe: usize, bytes: usize, items: usize) -> TransferPlan {
        let reachable = self.ipc.lookup(pe).is_some();
        let loc = self.loc_of(pe);
        self.rt.xfer.plan_p2p(kind, reachable, loc, bytes, items)
    }

    // ----------------------------------------------------- ring plumbing --

    /// Post a ring message and block for its completion payload.
    pub(crate) fn proxied_blocking(&self, mut msg: Message) -> u64 {
        let pool = self.completions().clone();
        let token = pool.alloc();
        msg.completion = token.index;
        msg.src_pe = self.pe() as u32;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.ring().send(msg);
        pool.wait(token)
    }

    /// Post a fire-and-forget ring message (tracked so `quiet` flushes it).
    pub(crate) fn proxied_ff(&self, mut msg: Message) {
        msg.completion = COMPLETION_NONE;
        msg.src_pe = self.pe() as u32;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.track.note_fire_and_forget();
        self.ring().send(msg);
    }

    pub(crate) fn check_proxy_status(&self, status: u64, what: &str, pe: usize) {
        match status {
            PROXY_OK => {}
            PROXY_ERR_UNREGISTERED => panic!(
                "{what} to PE {pe} failed: target heap not FI_HMEM-registered (strict mode)"
            ),
            other => panic!("{what} to PE {pe} failed: proxy status {other}"),
        }
    }

    // -------------------------------------------------- context helpers --

    #[inline]
    pub(crate) fn loc_of(&self, pe: usize) -> Locality {
        self.rt.cost.locality(self.pe(), pe)
    }

    #[inline]
    pub(crate) fn my_gpu(&self) -> usize {
        self.rt.topo().global_gpu_of(self.pe())
    }

    /// Queue-aware modeled duration of this plan's engine execution.
    fn engine_exec_ns(&self, plan: &TransferPlan) -> f64 {
        self.rt.cost.copy_engine_ns(
            self.my_gpu(),
            plan.loc,
            plan.bytes,
            self.rt.xfer.immediate_cl,
            false,
            true,
        )
    }

    fn nic_exec_ns(&self, pe: usize, bytes: usize) -> f64 {
        let registered = self.rt.transport.is_registered(pe);
        self.rt.cost.internode_ns(bytes, registered, true)
    }

    // ------------------------------------------------- blocking executors --

    /// Shared tail of the proxied blocking routes: compose the one RMA
    /// wire message, block on the proxy, then charge + count by route.
    fn exec_proxied_blocking(
        &self,
        plan: &TransferPlan,
        op: RingOp,
        what: &str,
        pe: usize,
        dst_off: u64,
        src_off: u64,
    ) {
        let m = rma_message(op, pe, dst_off, src_off, plan.bytes);
        let status = self.proxied_blocking(m);
        self.check_proxy_status(status, what, pe);
        match plan.route {
            Route::CopyEngine => {
                let ns = self.engine_exec_ns(plan);
                self.clock.advance(ns);
                self.rt.xfer.record(plan, ns);
                Metrics::add(&self.rt.metrics.bytes_copy_engine, plan.bytes as u64);
            }
            Route::Nic => {
                self.clock.advance(self.nic_exec_ns(pe, plan.bytes));
                Metrics::add(&self.rt.metrics.bytes_nic, plan.bytes as u64);
            }
            Route::LoadStore => unreachable!("load/store never posts a ring message"),
        }
    }

    /// Execute a planned blocking put of `src` into `pe`'s heap at
    /// `dst_off`.
    pub(crate) fn exec_put(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).write(dst_off, src);
                self.clock.advance(plan.modeled_ns);
                self.rt.xfer.record(plan, plan.modeled_ns);
                Metrics::add(&self.rt.metrics.bytes_loadstore, plan.bytes as u64);
            }
            Route::CopyEngine | Route::Nic => self.exec_proxied_blocking(
                plan,
                RingOp::Put,
                "put",
                pe,
                dst_off as u64,
                src.as_ptr() as u64,
            ),
        }
    }

    /// Execute a planned blocking get from `pe`'s heap at `src_off`.
    pub(crate) fn exec_get(
        &self,
        plan: &TransferPlan,
        pe: usize,
        src_off: usize,
        dst: &mut [u8],
    ) {
        match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).read(src_off, dst);
                self.clock.advance(plan.modeled_ns);
                self.rt.xfer.record(plan, plan.modeled_ns);
                Metrics::add(&self.rt.metrics.bytes_loadstore, plan.bytes as u64);
            }
            Route::CopyEngine | Route::Nic => self.exec_proxied_blocking(
                plan,
                RingOp::Get,
                "get",
                pe,
                dst.as_mut_ptr() as u64,
                src_off as u64,
            ),
        }
    }

    // ---------------------------------------------------- NBI executors --

    /// Execute a planned non-blocking put: data moves eagerly (Rust borrow
    /// safety — stronger than the spec's contract), the *modeled*
    /// completion defers to the tracker and collapses at `quiet`.
    pub(crate) fn exec_put_nbi(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        let issue = self.rt.cost.ring_post_ns();
        let full = match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).write(dst_off, src);
                Metrics::add(&self.rt.metrics.bytes_loadstore, plan.bytes as u64);
                self.rt.xfer.record(plan, plan.modeled_ns);
                plan.modeled_ns
            }
            Route::CopyEngine => {
                // Eager movement; the modeled engine transfer completes at
                // the horizon.
                self.rt.heaps.heap(pe).write(dst_off, src);
                Metrics::add(&self.rt.metrics.bytes_copy_engine, plan.bytes as u64);
                let ns = self.engine_exec_ns(plan);
                self.rt.xfer.record(plan, ns);
                ns
            }
            Route::Nic => {
                let dummy = SimClock::new();
                self.rt
                    .transport
                    .put_from_ptr(src.as_ptr() as u64, pe, dst_off, plan.bytes, &dummy)
                    .expect("put_nbi transport");
                Metrics::add(&self.rt.metrics.bytes_nic, plan.bytes as u64);
                self.nic_exec_ns(pe, plan.bytes)
            }
        };
        self.clock.advance(issue);
        let done_at = self.clock.now_ns() + (full - issue).max(0.0);
        self.track.defer(done_at);
    }

    /// Execute a planned non-blocking get (eager movement, deferred model).
    pub(crate) fn exec_get_nbi(
        &self,
        plan: &TransferPlan,
        pe: usize,
        src_off: usize,
        dst: &mut [u8],
    ) {
        let issue = self.rt.cost.ring_post_ns();
        let full = match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).read(src_off, dst);
                Metrics::add(&self.rt.metrics.bytes_loadstore, plan.bytes as u64);
                self.rt.xfer.record(plan, plan.modeled_ns);
                plan.modeled_ns
            }
            Route::CopyEngine => {
                self.rt.heaps.heap(pe).read(src_off, dst);
                Metrics::add(&self.rt.metrics.bytes_copy_engine, plan.bytes as u64);
                let ns = self.engine_exec_ns(plan);
                self.rt.xfer.record(plan, ns);
                ns
            }
            Route::Nic => {
                let dummy = SimClock::new();
                self.rt
                    .transport
                    .get_to_ptr(pe, src_off, dst.as_mut_ptr() as u64, plan.bytes, &dummy)
                    .expect("get_nbi transport");
                Metrics::add(&self.rt.metrics.bytes_nic, plan.bytes as u64);
                self.nic_exec_ns(pe, plan.bytes)
            }
        };
        self.clock.advance(issue);
        let done_at = self.clock.now_ns() + (full - issue).max(0.0);
        self.track.defer(done_at);
    }

    // ------------------------------------------------ signal executor ----

    /// Execute a planned remote put-with-signal: one proxied message
    /// carries payload pointer + signal update so the proxy orders them on
    /// the wire (put; fence; signal) — paper §9.8.3 semantics.
    pub(crate) fn exec_put_signal_remote(
        &self,
        plan: &TransferPlan,
        pe: usize,
        dst_off: usize,
        src: &[u8],
        sig_off: usize,
        signal: u64,
        sig_add: bool,
    ) {
        let mut m = rma_message(
            RingOp::PutSignal,
            pe,
            dst_off as u64,
            src.as_ptr() as u64,
            plan.bytes,
        );
        m.flags |= if sig_add { 1 } else { 0 };
        m.inline_val = signal;
        m.inline_val2 = sig_off as u64;
        let status = self.proxied_blocking(m);
        self.check_proxy_status(status, "put_signal", pe);
        // Payload + 8-byte signal word cross the wire.
        self.clock.advance(self.nic_exec_ns(pe, plan.bytes + 8));
        Metrics::add(&self.rt.metrics.bytes_nic, plan.bytes as u64 + 8);
    }

    // ------------------------------------------------- AMO / inline ops --

    /// Proxied atomic: compose the `Amo` ring message, execute remotely,
    /// and charge the fetch round trip or the fire-and-forget post.
    /// Returns the fetched old value (0 for non-fetching kinds).
    pub(crate) fn proxied_amo(
        &self,
        pe: usize,
        dst_off: usize,
        dtype: u8,
        kind: AmoKind,
        operand: u64,
        comparand: u64,
        fetching: bool,
    ) -> u64 {
        let mut m = Message::nop();
        m.op = RingOp::Amo as u8;
        m.dtype = dtype;
        m.flags = kind as u8 as u16;
        m.pe = pe as u32;
        m.dst_off = dst_off as u64;
        m.inline_val = operand;
        m.inline_val2 = comparand;
        if fetching {
            let old = self.proxied_blocking(m);
            self.clock
                .advance(self.rt.cost.fetch_atomic_ns(Locality::Remote));
            old
        } else {
            self.proxied_ff(m);
            self.clock.advance(self.rt.cost.ring_post_ns());
            0
        }
    }

    /// Proxied inline scalar put (≤ 8 bytes ride inside the message):
    /// locally complete as soon as the message is posted.
    pub(crate) fn proxied_put_inline(
        &self,
        pe: usize,
        dst_off: usize,
        dtype: u8,
        len: usize,
        raw: u64,
    ) {
        let mut m = Message::nop();
        m.op = RingOp::PutInline as u8;
        m.dtype = dtype;
        m.pe = pe as u32;
        m.dst_off = dst_off as u64;
        m.len = len as u64;
        m.inline_val = raw;
        self.proxied_ff(m);
        self.clock.advance(self.rt.cost.ring_post_ns());
        Metrics::add(&self.rt.metrics.bytes_nic, len as u64);
    }
}
