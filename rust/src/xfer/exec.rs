//! Executors: carry out a [`TransferPlan`] (plan→**execute**→complete).
//!
//! One executor per [`Route`]:
//! * `LoadStore` — direct stores/loads into the peer heap (the real bytes
//!   move through the shared-memory substrate), charged at the Xe-Link
//!   work-item store rate (§III-B);
//! * `CopyEngine` — reverse offload through the batched command stream
//!   ([`super::stream`]): the payload is staged into the symmetric-heap
//!   slab, a descriptor joins the current plan-group, and one
//!   `RingOp::Batch` doorbell submits the group; the proxy runs each
//!   entry on a real `DeviceAddr` command list (immediate or standard,
//!   per descriptor — §III-C);
//! * `Nic` — same stream, but the proxy forwards staged entries to the
//!   OFI transport (inter-node, §III-D).
//!
//! Large engine-route transfers run as a **striped chunk pipeline**
//! (ISSUE 3): the planner picks a chunk size and stripe width, the
//! executor slices the payload into slab-staged chunks carrying
//! continuation fields (chunk id, count, engine hint — `ringbuf::batch`),
//! and the proxy dispatches them onto the least-loaded engines with one
//! standard command list per engine per batch. Slab pressure flushes
//! earlier chunks fire-and-forget while later ones stage, so staging of
//! chunk *k+1* overlaps engine execution of chunk *k*. Oversized payloads
//! (> slab) therefore chunk *through* the slab; the original
//! one-message-per-op raw-pointer path (`FLAG_RAW_PTR`) survives only
//! when a single chunk cannot fit an empty slab.
//!
//! Executors feed observed (modeled) durations back to the planner so
//! `CutoverMode::Adaptive` learns online, and reserve/release the
//! per-engine byte backlog that makes the planner occupancy-aware and
//! striped placement balanced.
//!
//! Hierarchical collectives (ISSUE 7) compose onto the same machinery
//! rather than adding a fourth route: intra-node stages are fan-outs
//! whose engine-route blocks chunk through [`chunk_iter`] with
//! engine/rail hints, and each inter-node leader hop is priced and
//! recorded as a composed p2p `Nic` plan, so rail calibration and
//! backlog occupancy reach collective schedules too.

use crate::coordinator::metrics::{Metrics, PathIdx};
use crate::ishmem::PeCtx;
use crate::ringbuf::message::AmoKind;
use crate::ringbuf::{BatchDescriptor, Message, RingOp, COMPLETION_NONE};
use crate::sim::topology::Locality;
use crate::sim::SimClock;

use super::plan::{ChainStage, OpKind, Route, TransferPlan};

/// Message flag: `src_off`/`dst_off` is a raw in-process pointer (the
/// initiator's private buffer), not a symmetric-heap offset.
pub(crate) const FLAG_RAW_PTR: u16 = 1 << 8;

/// Completion payloads for non-fetching proxied ops. `PROXY_NACK` is the
/// reliability layer's "checksum verification failed / chunk dropped"
/// status: the low byte is the code, the bits above it a per-entry
/// failure mask (`stream::{encode_nack, decode_nack}`).
pub(crate) const PROXY_OK: u64 = 0;
pub(crate) const PROXY_ERR_UNREGISTERED: u64 = 1;
pub(crate) const PROXY_NACK: u64 = 2;

/// Static op name for a ring message byte (deadline error reporting).
pub(crate) fn proxy_op_name(op: u8) -> &'static str {
    match RingOp::from_u8(op) {
        Some(RingOp::Put) => "put",
        Some(RingOp::Get) => "get",
        Some(RingOp::PutInline) => "put-inline",
        Some(RingOp::Amo) => "amo",
        Some(RingOp::Quiet) => "quiet",
        Some(RingOp::PutSignal) => "put-signal",
        Some(RingOp::Barrier) => "barrier",
        Some(RingOp::Batch) => "batch",
        _ => "proxied-op",
    }
}

/// Uniform chunk geometry of a striped transfer: yields `(idx, offset,
/// len)` for every chunk. Used by the collectives fan-out, which assigns
/// lanes with its own fan-out-wide counter; the p2p executors use the
/// ramp-aware [`chunk_layout`] instead.
pub(crate) fn chunk_iter(
    bytes: usize,
    chunk: usize,
) -> impl Iterator<Item = (usize, usize, usize)> {
    let chunk = chunk.max(1);
    (0..bytes.div_ceil(chunk)).map(move |i| {
        let off = i * chunk;
        (i, off, chunk.min(bytes - off))
    })
}

/// Ramped chunk geometry of a striped transfer: the first `ramp_chunks`
/// chunks use the reduced `ramp_len` fill (so the first engine/rail
/// starts earlier — `stripe.ramp_factor`), then geometry grows to the
/// planned `chunk` size. Yields contiguous `(idx, offset, len)` triples
/// with monotone ids covering `bytes` exactly; `ramp_len == chunk`
/// reproduces the un-ramped slicing of [`chunk_iter`].
pub fn chunk_layout(
    bytes: usize,
    chunk: usize,
    ramp_len: usize,
    ramp_chunks: usize,
) -> Vec<(usize, usize, usize)> {
    let chunk = chunk.max(1);
    let ramp_len = ramp_len.clamp(1, chunk);
    let mut out = Vec::with_capacity(bytes.div_ceil(chunk) + ramp_chunks);
    let (mut off, mut idx) = (0usize, 0usize);
    while off < bytes {
        let full = if idx < ramp_chunks { ramp_len } else { chunk };
        let len = full.min(bytes - off);
        out.push((idx, off, len));
        off += len;
        idx += 1;
    }
    out
}

/// Chunk count of [`chunk_layout`] in O(1) — the charge model needs only
/// the count, not the slices (property-tested to match the layout).
pub fn chunk_layout_len(bytes: usize, chunk: usize, ramp_len: usize, ramp_chunks: usize) -> usize {
    let chunk = chunk.max(1);
    let ramp_len = ramp_len.clamp(1, chunk);
    let ramp_span = ramp_chunks.saturating_mul(ramp_len);
    if bytes <= ramp_span {
        bytes.div_ceil(ramp_len)
    } else {
        ramp_chunks + (bytes - ramp_span).div_ceil(chunk)
    }
}

/// Which backlog ledger a striped transfer's lanes live on: the source
/// GPU's copy engines (intra-node, §III-C) or the source node's NIC rails
/// (inter-node, §III-D). The lane index rides the descriptor continuation
/// field either way (`BatchDescriptor::with_chunk`), and reserve/release
/// and the NBI tracker ledger dispatch on the kind.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Lanes {
    Engines { gpu: usize },
    Rails { node: usize },
}

/// Compose a reverse-offload RMA ring message (the raw-pointer fallback
/// wire format shared by oversized put/get traffic).
pub(crate) fn rma_message(
    op: RingOp,
    pe: usize,
    dst_off: u64,
    src_off: u64,
    len: usize,
) -> Message {
    let mut m = Message::nop();
    m.op = op as u8;
    m.flags = FLAG_RAW_PTR;
    m.pe = pe as u32;
    m.dst_off = dst_off;
    m.src_off = src_off;
    m.len = len as u64;
    m
}

impl PeCtx {
    // ----------------------------------------------------------- planning --

    /// Plan a point-to-point transfer to `pe`: IPC-table reachability
    /// lookup (§III-G.1 step 2) + locality classification, then the
    /// engine's path decision — occupancy-aware via this PE's GPU.
    pub(crate) fn plan_to(&self, kind: OpKind, pe: usize, bytes: usize, items: usize) -> TransferPlan {
        let reachable = self.ipc.lookup(pe).is_some();
        let loc = self.loc_of(pe);
        self.rt
            .xfer
            .plan_p2p_from(Some(self.my_gpu()), kind, reachable, loc, bytes, items)
    }

    // ----------------------------------------------------- ring plumbing --

    /// Post a ring message and block for its completion payload. Flushes
    /// the pending command stream first: a directly-posted message must
    /// not overtake entries appended before it (per-PE FIFO).
    pub(crate) fn proxied_blocking(&self, mut msg: Message) -> u64 {
        self.stream_flush_ff();
        let pool = self.completions().clone();
        let token = pool.alloc();
        msg.completion = token.index;
        msg.src_pe = self.pe() as u32;
        let what = proxy_op_name(msg.op);
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.ring().send(msg);
        // Deadline-bounded under `xfer.op_timeout_ms` (0 = the original
        // unbounded spin, bit-for-bit).
        self.proxy_wait_completion(token, what, 0)
    }

    /// Post a fire-and-forget ring message (tracked so `quiet` flushes
    /// it). Flushes the pending command stream first (FIFO, as above).
    pub(crate) fn proxied_ff(&self, mut msg: Message) {
        self.stream_flush_ff();
        msg.completion = COMPLETION_NONE;
        msg.src_pe = self.pe() as u32;
        Metrics::add(&self.rt.metrics.ring_messages, 1);
        self.track.note_fire_and_forget();
        self.ring().send(msg);
    }

    pub(crate) fn check_proxy_status(&self, status: u64, what: &str, pe: usize) {
        match status {
            PROXY_OK => {}
            PROXY_ERR_UNREGISTERED => panic!(
                "{what} to PE {pe} failed: target heap not FI_HMEM-registered (strict mode)"
            ),
            other => panic!("{what} to PE {pe} failed: proxy status {other}"),
        }
    }

    // -------------------------------------------------- context helpers --

    #[inline]
    pub(crate) fn loc_of(&self, pe: usize) -> Locality {
        self.rt.cost.locality(self.pe(), pe)
    }

    #[inline]
    pub(crate) fn my_gpu(&self) -> usize {
        self.rt.topo().global_gpu_of(self.pe())
    }

    /// The command-list flavour this transfer's descriptor requests
    /// (per-op CL policy, §III-C).
    #[inline]
    fn standard_cl_for(&self, bytes: usize) -> bool {
        !self.rt.xfer.cl_immediate_for(bytes)
    }

    /// The lane set a striped plan's chunks spread over: the `width`
    /// least-loaded copy engines of this PE's GPU for engine routes, the
    /// least-loaded NIC rails of its node for remote routes.
    fn lanes_for(&self, plan: &TransferPlan) -> (Lanes, Vec<usize>) {
        match plan.route {
            Route::CopyEngine => {
                let gpu = self.my_gpu();
                (Lanes::Engines { gpu }, self.rt.cost.engine_pick(gpu, plan.stripe_width))
            }
            Route::Nic => {
                let node = self.node();
                (Lanes::Rails { node }, self.rt.cost.rail_pick(node, plan.stripe_width))
            }
            Route::LoadStore => unreachable!("load/store transfers never stripe"),
        }
    }

    /// Register accepted-but-incomplete work on one lane of the shared
    /// cost model (the planner's occupancy fold reads it).
    fn lane_reserve(&self, lanes: Lanes, lane: usize, bytes: u64) {
        match lanes {
            Lanes::Engines { gpu } => self.rt.cost.engine_reserve_on(gpu, lane, bytes),
            Lanes::Rails { node } => self.rt.cost.rail_reserve_on(node, lane, bytes),
        }
    }

    /// Retire work previously reserved with [`Self::lane_reserve`].
    fn lane_release(&self, lanes: Lanes, lane: usize, bytes: u64) {
        match lanes {
            Lanes::Engines { gpu } => self.rt.cost.engine_release_on(gpu, lane, bytes),
            Lanes::Rails { node } => self.rt.cost.rail_release_on(node, lane, bytes),
        }
    }

    /// Park an NBI reservation in the completion tracker's matching
    /// per-lane ledger until `quiet` releases it.
    fn lane_note_nbi(&self, lanes: Lanes, lane: usize, bytes: u64) {
        match lanes {
            Lanes::Engines { .. } => self.track.note_engine_bytes(lane, bytes),
            Lanes::Rails { .. } => self.track.note_rail_bytes(lane, bytes),
        }
    }

    /// Chunk geometry this plan's executor slices the payload into:
    /// ramped first fills when `stripe.ramp_factor` < 1, the planner's
    /// uniform `chunk_bytes` otherwise.
    fn plan_layout(&self, plan: &TransferPlan) -> Vec<(usize, usize, usize)> {
        let stripe = &self.rt.cost.params.stripe;
        chunk_layout(
            plan.bytes,
            plan.chunk_bytes,
            stripe.first_fill_bytes(plan.chunk_bytes),
            stripe.ramp_chunks,
        )
    }

    /// Chunk count of the executed geometry (= `plan.chunks()` unless the
    /// ramp added leading sub-chunks).
    fn chunk_total(&self, plan: &TransferPlan) -> usize {
        let stripe = &self.rt.cost.params.stripe;
        if plan.chunks() <= 1 || !stripe.ramp_enabled() {
            plan.chunks()
        } else {
            chunk_layout_len(
                plan.bytes,
                plan.chunk_bytes,
                stripe.first_fill_bytes(plan.chunk_bytes),
                stripe.ramp_chunks,
            )
        }
    }

    /// Queue-aware modeled duration of this plan's engine execution: the
    /// striped chunk pipeline for chunked plans, the legacy single
    /// transfer otherwise (the CL policy is per chunk either way).
    fn engine_exec_ns(&self, plan: &TransferPlan) -> f64 {
        self.engine_exec_chunks_ns(plan, plan.chunks())
    }

    /// Engine execution charge at an explicit chunk count (the ramped
    /// geometry can add chunks beyond the planner's uniform slicing).
    fn engine_exec_chunks_ns(&self, plan: &TransferPlan, chunks: usize) -> f64 {
        self.rt.cost.copy_engine_striped_ns(
            self.my_gpu(),
            plan.loc,
            plan.bytes,
            self.rt.xfer.cl_immediate_for(plan.chunk_bytes.min(plan.bytes)),
            plan.stripe_width,
            chunks,
        )
    }

    /// Queue-aware single-engine charge for a chunked plan that degraded
    /// entirely to the raw-pointer path (tiny-slab / depth-1 configs):
    /// the transfer actually ran as one un-striped message, so charging
    /// the striped pipeline would under-model it.
    fn engine_exec_raw_ns(&self, plan: &TransferPlan) -> f64 {
        self.rt.cost.copy_engine_striped_ns(
            self.my_gpu(),
            plan.loc,
            plan.bytes,
            self.rt.xfer.cl_immediate_for(plan.bytes),
            1,
            1,
        )
    }

    fn nic_exec_ns(&self, pe: usize, bytes: usize) -> f64 {
        let registered = self.rt.transport.is_registered(pe);
        self.rt.cost.internode_ns(bytes, registered, true)
    }

    /// Record a modeled service time for the wall-vs-model comparison
    /// tables (`rishmem figure service-delta`): the executor-side half of
    /// the per-(path, size-bucket) ledger the proxy fills with wall clocks.
    fn note_model_service(&self, path: PathIdx, bytes: usize, ns: f64) {
        self.rt.metrics.add_service_model(path, bytes as u64, ns as u64);
    }

    /// Queue-aware modeled duration of a chunked plan's rail execution:
    /// the rail-striped RDMA at an explicit chunk count (unregistered
    /// targets bounce un-striped).
    fn nic_exec_striped_ns(&self, pe: usize, plan: &TransferPlan, chunks: usize) -> f64 {
        let registered = self.rt.transport.is_registered(pe);
        self.rt
            .cost
            .internode_striped_ns(plan.bytes, registered, true, plan.stripe_width, chunks)
    }

    /// Modeled duration of the whole striped chunk pipeline (engine *or*
    /// rail lanes): staging of chunk *k+1* overlaps engine/rail execution
    /// of chunk *k* (slab double-buffering), so the steady state runs at
    /// the slower of the two chains. The pipeline fill — the first
    /// chunk's staging — hides under the ring round trip except for its
    /// last `chunk_min` bytes (the route's own minimum): at the HBM
    /// staging rate a slab-capped chunk stages in less than the ~5 µs
    /// RTT, so one minimum-chunk staging bounds the serial fill. (This
    /// also keeps the modeled charge continuous across the
    /// un-chunked→chunked boundary, where the staged path charges one
    /// full serial staging copy.) Ramped first chunks
    /// (`stripe.ramp_factor` < 1) shrink the serial fill term — the first
    /// lane starts earlier — at the price of the extra chunk startups
    /// already inside `exec`.
    fn chunk_pipeline_ns(&self, pe: usize, plan: &TransferPlan) -> f64 {
        let chunks = self.chunk_total(plan);
        let (exec, chunk_min) = match plan.route {
            Route::CopyEngine => (
                self.engine_exec_chunks_ns(plan, chunks),
                self.rt.cost.params.ce.chunk_min_bytes,
            ),
            Route::Nic => (
                self.nic_exec_striped_ns(pe, plan, chunks),
                self.rt.cost.params.nic.rail_chunk_min_bytes,
            ),
            Route::LoadStore => unreachable!("load/store transfers never stripe"),
        };
        let staging = self.rt.cost.staging_copy_ns(plan.bytes);
        let fill_bytes = chunk_min.min(plan.chunk_bytes).min(plan.bytes);
        let fill = self.rt.cost.params.stripe.first_fill_bytes(fill_bytes);
        exec.max(staging) + self.rt.cost.staging_copy_ns(fill)
    }

    // ------------------------------------------------- blocking executors --

    /// Charge + count a completed proxied route (shared by the batched
    /// and raw-fallback blocking paths).
    fn charge_proxied_blocking(&self, plan: &TransferPlan, pe: usize) {
        match plan.route {
            Route::CopyEngine => {
                let ns = self.engine_exec_ns(plan);
                self.clock.advance(ns);
                self.rt.xfer.record(plan, ns);
                self.note_model_service(PathIdx::CopyEngine, plan.bytes, ns);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::CopyEngine, plan.loc, plan.bytes as u64);
            }
            Route::Nic => {
                let ns = self.nic_exec_ns(pe, plan.bytes);
                self.clock.advance(ns);
                self.note_model_service(PathIdx::Nic, plan.bytes, ns);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::Nic, Locality::Remote, plan.bytes as u64);
            }
            Route::LoadStore => unreachable!("load/store never posts a ring message"),
        }
    }

    /// Shared choreography of the staged blocking routes: append the
    /// descriptor, hold the engine-queue reservation across the blocking
    /// flush (so concurrent planners see the backlog), run the caller's
    /// post-flush step (e.g. copying a get result out of the slab), then
    /// charge + count by route. The reserve/release pairing lives only
    /// here.
    fn exec_staged_blocking(
        &self,
        plan: &TransferPlan,
        pe: usize,
        mut desc: BatchDescriptor,
        after_flush: impl FnOnce(&Self),
    ) {
        let engine = (plan.route == Route::CopyEngine).then(|| {
            let gpu = self.my_gpu();
            let eng = self.rt.cost.engine_pick(gpu, 1)[0];
            self.rt.cost.engine_reserve_on(gpu, eng, plan.bytes as u64);
            eng
        });
        if let Some(eng) = engine {
            // Carry the picked engine as a 1-chunk hint so the proxy's
            // dispatch and per-engine metrics agree with the reservation.
            desc = desc.with_chunk(0, 1, eng as u8);
        }
        self.stream_append(desc, 1);
        self.stream_flush_blocking();
        after_flush(self);
        self.charge_proxied_blocking(plan, pe);
        if let Some(eng) = engine {
            self.rt
                .cost
                .engine_release_on(self.my_gpu(), eng, plan.bytes as u64);
        }
    }

    /// Raw-pointer fallback for payloads the staging slab cannot hold:
    /// compose the one RMA wire message, block on the proxy, then charge
    /// + count by route.
    fn exec_proxied_blocking(
        &self,
        plan: &TransferPlan,
        op: RingOp,
        what: &str,
        pe: usize,
        dst_off: u64,
        src_off: u64,
    ) {
        let m = rma_message(op, pe, dst_off, src_off, plan.bytes);
        let status = self.proxied_blocking(m);
        self.check_proxy_status(status, what, pe);
        self.charge_proxied_blocking(plan, pe);
    }

    /// Execute a planned blocking put of `src` into `pe`'s heap at
    /// `dst_off`.
    pub(crate) fn exec_put(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).write(dst_off, src);
                self.clock.advance(plan.modeled_ns);
                self.rt.xfer.record(plan, plan.modeled_ns);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::LoadStore, plan.loc, plan.bytes as u64);
            }
            Route::CopyEngine | Route::Nic if plan.chunks() > 1 => {
                self.exec_put_chunked(plan, pe, dst_off, src)
            }
            Route::CopyEngine | Route::Nic => match self.stream_stage_payload(src) {
                Some(src_off) => {
                    let desc = BatchDescriptor::put(pe, dst_off, src_off, plan.bytes)
                        .with_standard_cl(self.standard_cl_for(plan.bytes));
                    self.exec_staged_blocking(plan, pe, desc, |_| {});
                }
                None => self.exec_proxied_blocking(
                    plan,
                    RingOp::Put,
                    "put",
                    pe,
                    dst_off as u64,
                    src.as_ptr() as u64,
                ),
            },
        }
    }

    /// Blocking striped put (engine *or* rail route): slice the payload
    /// into slab-staged chunks, each descriptor carrying its chunk id and
    /// least-loaded lane hint (engine slot intra-node, NIC rail slot
    /// inter-node). Slab pressure flushes earlier chunks fire-and-forget
    /// while later ones stage (double-buffering), the final blocking flush
    /// retires the whole pipeline, and one striped charge covers the
    /// transfer.
    fn exec_put_chunked(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        let (lanes, slots) = self.lanes_for(plan);
        let layout = self.plan_layout(plan);
        let total = layout.len();
        let mut reserved: Vec<(usize, u64)> = Vec::with_capacity(total);
        let mut staged = 0usize; // bytes staged; chunks staged == reserved.len()
        for (idx, off, len) in layout {
            let Some(slab_off) = self.stream_stage_payload_uncharged(&src[off..off + len])
            else {
                break; // degenerate slab: ship the tail on the raw path below
            };
            let lane = slots[idx % slots.len()];
            let desc = BatchDescriptor::put(pe, dst_off + off, slab_off, len)
                .with_standard_cl(self.standard_cl_for(len))
                .with_chunk(idx as u32, total as u32, lane as u8)
                .with_transfer_bytes(plan.bytes as u64);
            self.stream_append(desc, 1);
            self.lane_reserve(lanes, lane, len as u64);
            reserved.push((lane, len as u64));
            staged += len;
        }
        if staged < src.len() {
            // A single chunk cannot fit an empty slab (tiny-slab config):
            // the raw-pointer message delivers the tail, flushing any
            // staged chunks ahead of it (per-PE FIFO; the proxy routes it
            // over the engines or the NIC by target locality).
            let m = rma_message(
                RingOp::Put,
                pe,
                (dst_off + staged) as u64,
                src[staged..].as_ptr() as u64,
                src.len() - staged,
            );
            let status = self.proxied_blocking(m);
            self.check_proxy_status(status, "put", pe);
        } else {
            self.stream_flush_blocking();
        }
        self.charge_chunked(plan, pe, reserved.len());
        for (lane, bytes) in reserved {
            self.lane_release(lanes, lane, bytes);
        }
    }

    /// Charge + count a completed chunked transfer: the striped pipeline
    /// (engine or rail flavour) when chunks actually flowed through the
    /// slab, the un-striped single-transfer model when the whole payload
    /// degraded to the raw-pointer path — and only real stripes hit the
    /// stripe metrics.
    fn charge_chunked(&self, plan: &TransferPlan, pe: usize, chunks_staged: usize) {
        let (ns, path, loc) = match plan.route {
            Route::CopyEngine => {
                let ns = if chunks_staged == 0 {
                    self.engine_exec_raw_ns(plan)
                } else {
                    self.chunk_pipeline_ns(pe, plan)
                };
                (ns, PathIdx::CopyEngine, plan.loc)
            }
            Route::Nic => {
                let ns = if chunks_staged == 0 {
                    self.nic_exec_ns(pe, plan.bytes)
                } else {
                    self.chunk_pipeline_ns(pe, plan)
                };
                (ns, PathIdx::Nic, Locality::Remote)
            }
            Route::LoadStore => unreachable!("load/store transfers never chunk"),
        };
        self.clock.advance(ns);
        self.rt.xfer.record(plan, ns);
        self.note_model_service(path, plan.bytes, ns);
        self.rt.metrics.add_path_bytes(path, loc, plan.bytes as u64);
        if chunks_staged > 0 {
            self.rt.metrics.add_stripe(chunks_staged);
        }
    }

    /// Execute a planned blocking get from `pe`'s heap at `src_off`.
    pub(crate) fn exec_get(
        &self,
        plan: &TransferPlan,
        pe: usize,
        src_off: usize,
        dst: &mut [u8],
    ) {
        match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).read(src_off, dst);
                self.clock.advance(plan.modeled_ns);
                self.rt.xfer.record(plan, plan.modeled_ns);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::LoadStore, plan.loc, plan.bytes as u64);
            }
            Route::CopyEngine | Route::Nic if plan.chunks() > 1 => {
                self.exec_get_chunked(plan, pe, src_off, dst)
            }
            Route::CopyEngine | Route::Nic => match self.stream_slab_alloc(plan.bytes) {
                Some(slab_off) => {
                    let desc = BatchDescriptor::get(pe, slab_off, src_off, plan.bytes)
                        .with_standard_cl(self.standard_cl_for(plan.bytes));
                    self.exec_staged_blocking(plan, pe, desc, |s| {
                        // The proxy landed the result in the slab; copy it
                        // out. The claim was just released, but nothing
                        // can reuse the arena before this single-threaded
                        // PE reads it.
                        s.rt.heaps.heap(s.pe()).read(slab_off, dst);
                        s.clock.advance(s.rt.cost.staging_copy_ns(plan.bytes));
                    });
                }
                None => self.exec_proxied_blocking(
                    plan,
                    RingOp::Get,
                    "get",
                    pe,
                    dst.as_mut_ptr() as u64,
                    src_off as u64,
                ),
            },
        }
    }

    /// Blocking striped get (engine *or* rail route): windows of
    /// chunk-sized slab claims. Each window appends get descriptors
    /// (results land in the claimed slab regions), flushes blocking, then
    /// copies the results out *before* the next window can rewind the
    /// arena over them. Chunks carry ids and lane hints exactly like
    /// striped puts.
    fn exec_get_chunked(&self, plan: &TransferPlan, pe: usize, src_off: usize, dst: &mut [u8]) {
        // Clean slate: a pending plan-group or in-flight batches would
        // pin slab space the windows need (and must not be force-flushed
        // mid-window).
        self.stream_quiet_drain();
        let (lanes, slots) = self.lanes_for(plan);
        let layout = self.plan_layout(plan);
        let total = layout.len();
        let mut done = 0usize; // bytes fully windowed
        let mut idx = 0usize; // chunks windowed
        'windows: while idx < total {
            let mut window: Vec<(usize, usize, usize)> = Vec::new(); // (slab, dst, len)
            let mut reserved: Vec<(usize, u64)> = Vec::new();
            while idx < total {
                // The window invariant — get descriptors stay *pending*
                // until this window's copy-out — would be violated by
                // stream_append's capacity fire-and-forget flush (a
                // flushed-and-drained batch releases its slab claims and
                // the rewound arena lets later chunks overwrite results
                // not yet copied out). Stop one entry short of the
                // trigger; at max_batch_depth 1 no window forms and the
                // raw tail below carries the whole get (per-op mode).
                if self.stream.pending_len() + 1 >= self.stream.max_depth() {
                    break;
                }
                let (i, off, len) = layout[idx];
                let Some(slab_off) = self.stream_slab_try_alloc(len) else { break };
                let lane = slots[i % slots.len()];
                let desc = BatchDescriptor::get(pe, slab_off, src_off + off, len)
                    .with_standard_cl(self.standard_cl_for(len))
                    .with_chunk(i as u32, total as u32, lane as u8)
                    .with_transfer_bytes(plan.bytes as u64);
                self.stream_append(desc, 1);
                self.lane_reserve(lanes, lane, len as u64);
                reserved.push((lane, len as u64));
                window.push((slab_off, off, len));
                done = off + len;
                idx += 1;
                // The size-adaptive flush can push a large get descriptor
                // out fire-and-forget the moment it is appended; end the
                // window before any further slab claim could drain that
                // batch and release this window's results pre-copy-out.
                if self.stream.pending_len() < window.len() {
                    break;
                }
            }
            if window.is_empty() {
                break 'windows; // tiny-slab config: raw tail below
            }
            self.stream_flush_blocking();
            // Copy-outs are not charged per chunk: window k's copy-out
            // overlaps window k+1's engine/rail execution; the aggregate
            // pipeline charge below covers the steady state + drain.
            for &(slab_off, doff, len) in &window {
                self.rt
                    .heaps
                    .heap(self.pe())
                    .read(slab_off, &mut dst[doff..doff + len]);
            }
            for (lane, bytes) in reserved {
                self.lane_release(lanes, lane, bytes);
            }
        }
        if done < dst.len() {
            let rest = dst.len() - done;
            let tail_ptr = dst[done..].as_mut_ptr() as u64;
            let m = rma_message(RingOp::Get, pe, tail_ptr, (src_off + done) as u64, rest);
            let status = self.proxied_blocking(m);
            self.check_proxy_status(status, "get", pe);
        }
        self.charge_chunked(plan, pe, idx);
    }

    // ---------------------------------------------------- NBI executors --

    /// Execute a planned non-blocking put. Batched routes stage the
    /// payload into the slab (so the source buffer may be reused on
    /// return) and defer real delivery to the proxy's batch service; the
    /// modeled completion defers to the tracker and collapses at `quiet`.
    pub(crate) fn exec_put_nbi(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        match plan.route {
            Route::LoadStore => {
                let issue = self.rt.cost.ring_post_ns();
                self.rt.heaps.heap(pe).write(dst_off, src);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::LoadStore, plan.loc, plan.bytes as u64);
                self.rt.xfer.record(plan, plan.modeled_ns);
                self.clock.advance(issue);
                let done_at = self.clock.now_ns() + (plan.modeled_ns - issue).max(0.0);
                self.track.defer(done_at);
            }
            Route::CopyEngine | Route::Nic if plan.chunks() > 1 => {
                self.exec_put_nbi_chunked(plan, pe, dst_off, src)
            }
            Route::CopyEngine | Route::Nic => match self.stream_stage_payload(src) {
                Some(src_off) => {
                    let mut desc = BatchDescriptor::put(pe, dst_off, src_off, plan.bytes)
                        .with_standard_cl(self.standard_cl_for(plan.bytes));
                    let full = match plan.route {
                        Route::CopyEngine => {
                            // Backlog stays reserved until quiet collapses
                            // the horizon — the planner sees it meanwhile.
                            // The 1-chunk hint keeps proxy dispatch and
                            // per-engine metrics on the reserved engine.
                            let gpu = self.my_gpu();
                            let eng = self.rt.cost.engine_pick(gpu, 1)[0];
                            self.rt.cost.engine_reserve_on(gpu, eng, plan.bytes as u64);
                            self.track.note_engine_bytes(eng, plan.bytes as u64);
                            desc = desc.with_chunk(0, 1, eng as u8);
                            let ns = self.engine_exec_ns(plan);
                            self.rt.xfer.record(plan, ns);
                            self.note_model_service(PathIdx::CopyEngine, plan.bytes, ns);
                            self.rt.metrics.add_path_bytes(
                                PathIdx::CopyEngine,
                                plan.loc,
                                plan.bytes as u64,
                            );
                            ns
                        }
                        Route::Nic => {
                            self.rt.metrics.add_path_bytes(
                                PathIdx::Nic,
                                Locality::Remote,
                                plan.bytes as u64,
                            );
                            let ns = self.nic_exec_ns(pe, plan.bytes);
                            self.note_model_service(PathIdx::Nic, plan.bytes, ns);
                            ns
                        }
                        Route::LoadStore => unreachable!(),
                    };
                    self.stream_append(desc, 1);
                    self.track.defer(self.clock.now_ns() + full);
                }
                None => self.exec_put_nbi_oversized(plan, pe, dst_off, src),
            },
        }
    }

    /// Non-blocking striped put (engine *or* rail route): chunks stage and
    /// append exactly like the blocking pipeline, but the per-lane
    /// reservations live in the completion tracker until `quiet` releases
    /// them, and every chunk aggregates into the one deferred completion
    /// (chunk ledger + a single horizon entry).
    fn exec_put_nbi_chunked(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        let (lanes, slots) = self.lanes_for(plan);
        let layout = self.plan_layout(plan);
        let total = layout.len();
        let mut staged_chunks = 0usize;
        let mut staged = 0usize;
        for (idx, off, len) in layout {
            let Some(slab_off) = self.stream_stage_payload_uncharged(&src[off..off + len])
            else {
                break; // tiny-slab tail handled below
            };
            let lane = slots[idx % slots.len()];
            let desc = BatchDescriptor::put(pe, dst_off + off, slab_off, len)
                .with_standard_cl(self.standard_cl_for(len))
                .with_chunk(idx as u32, total as u32, lane as u8)
                .with_transfer_bytes(plan.bytes as u64);
            self.stream_append(desc, 1);
            self.lane_reserve(lanes, lane, len as u64);
            self.lane_note_nbi(lanes, lane, len as u64);
            staged_chunks += 1;
            staged += len;
        }
        if staged < src.len() {
            // Tiny-slab tail: eager movement (the pre-chunking oversized
            // behavior), still one aggregated completion.
            match plan.route {
                Route::Nic => {
                    let dummy = SimClock::new();
                    self.rt
                        .transport
                        .put_from_ptr(
                            src[staged..].as_ptr() as u64,
                            pe,
                            dst_off + staged,
                            src.len() - staged,
                            &dummy,
                        )
                        .expect("put_nbi transport tail");
                }
                _ => self.rt.heaps.heap(pe).write(dst_off + staged, &src[staged..]),
            }
        }
        let (path, loc) = match plan.route {
            Route::Nic => (PathIdx::Nic, Locality::Remote),
            _ => (PathIdx::CopyEngine, plan.loc),
        };
        let ns = if staged_chunks == 0 {
            match plan.route {
                Route::Nic => self.nic_exec_ns(pe, plan.bytes),
                _ => self.engine_exec_raw_ns(plan),
            }
        } else {
            self.track.note_chunks(staged_chunks as u64);
            self.rt.metrics.add_stripe(staged_chunks);
            self.chunk_pipeline_ns(pe, plan)
        };
        self.rt.xfer.record(plan, ns);
        self.note_model_service(path, plan.bytes, ns);
        self.rt.metrics.add_path_bytes(path, loc, plan.bytes as u64);
        self.track.defer(self.clock.now_ns() + ns);
    }

    /// Oversized-NBI-put fallback: eager movement (the slab cannot hold
    /// the payload), modeled completion at the horizon — the pre-batching
    /// behavior.
    fn exec_put_nbi_oversized(&self, plan: &TransferPlan, pe: usize, dst_off: usize, src: &[u8]) {
        let issue = self.rt.cost.ring_post_ns();
        let full = match plan.route {
            Route::CopyEngine => {
                self.rt.heaps.heap(pe).write(dst_off, src);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::CopyEngine, plan.loc, plan.bytes as u64);
                let ns = self.engine_exec_ns(plan);
                self.rt.xfer.record(plan, ns);
                self.note_model_service(PathIdx::CopyEngine, plan.bytes, ns);
                ns
            }
            Route::Nic => {
                let dummy = SimClock::new();
                self.rt
                    .transport
                    .put_from_ptr(src.as_ptr() as u64, pe, dst_off, plan.bytes, &dummy)
                    .expect("put_nbi transport");
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::Nic, Locality::Remote, plan.bytes as u64);
                let ns = self.nic_exec_ns(pe, plan.bytes);
                self.note_model_service(PathIdx::Nic, plan.bytes, ns);
                ns
            }
            Route::LoadStore => unreachable!("handled by exec_put_nbi"),
        };
        self.clock.advance(issue);
        let done_at = self.clock.now_ns() + (full - issue).max(0.0);
        self.track.defer(done_at);
    }

    /// Execute a planned non-blocking get. Gets stay eager on every route:
    /// the destination borrow ends when this call returns, so deferring
    /// real movement to the proxy (as batched puts do) would dangle it.
    /// Only the *modeled* completion defers to the tracker.
    pub(crate) fn exec_get_nbi(
        &self,
        plan: &TransferPlan,
        pe: usize,
        src_off: usize,
        dst: &mut [u8],
    ) {
        let issue = self.rt.cost.ring_post_ns();
        let full = match plan.route {
            Route::LoadStore => {
                self.rt.heaps.heap(pe).read(src_off, dst);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::LoadStore, plan.loc, plan.bytes as u64);
                self.rt.xfer.record(plan, plan.modeled_ns);
                plan.modeled_ns
            }
            Route::CopyEngine => {
                self.rt.heaps.heap(pe).read(src_off, dst);
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::CopyEngine, plan.loc, plan.bytes as u64);
                let ns = self.engine_exec_ns(plan);
                self.rt.xfer.record(plan, ns);
                self.note_model_service(PathIdx::CopyEngine, plan.bytes, ns);
                ns
            }
            Route::Nic => {
                let dummy = SimClock::new();
                self.rt
                    .transport
                    .get_to_ptr(pe, src_off, dst.as_mut_ptr() as u64, plan.bytes, &dummy)
                    .expect("get_nbi transport");
                self.rt
                    .metrics
                    .add_path_bytes(PathIdx::Nic, Locality::Remote, plan.bytes as u64);
                // Movement is eager (borrow safety) but the modeled
                // completion honours the planned rail stripe.
                let ns = if plan.chunks() > 1 {
                    self.nic_exec_striped_ns(pe, plan, self.chunk_total(plan))
                } else {
                    self.nic_exec_ns(pe, plan.bytes)
                };
                self.note_model_service(PathIdx::Nic, plan.bytes, ns);
                ns
            }
        };
        self.clock.advance(issue);
        let done_at = self.clock.now_ns() + (full - issue).max(0.0);
        self.track.defer(done_at);
    }

    // ------------------------------------------------ signal executor ----

    /// Execute a planned remote put-with-signal: one proxied message
    /// carries payload pointer + signal update so the proxy orders them on
    /// the wire (put; fence; signal) — paper §9.8.3 semantics. Cannot
    /// batch (it is its own ordering fence); `proxied_blocking` flushes
    /// the pending stream first.
    pub(crate) fn exec_put_signal_remote(
        &self,
        plan: &TransferPlan,
        pe: usize,
        dst_off: usize,
        src: &[u8],
        sig_off: usize,
        signal: u64,
        sig_add: bool,
    ) {
        let mut m = rma_message(
            RingOp::PutSignal,
            pe,
            dst_off as u64,
            src.as_ptr() as u64,
            plan.bytes,
        );
        m.flags |= if sig_add { 1 } else { 0 };
        m.inline_val = signal;
        m.inline_val2 = sig_off as u64;
        let status = self.proxied_blocking(m);
        self.check_proxy_status(status, "put_signal", pe);
        // Payload + 8-byte signal word cross the wire.
        self.clock.advance(self.nic_exec_ns(pe, plan.bytes + 8));
        self.rt
            .metrics
            .add_path_bytes(PathIdx::Nic, Locality::Remote, plan.bytes as u64 + 8);
    }

    // ------------------------------------------------ triggered chains ---

    /// Stage list a fused put-signal chain is priced as: the payload
    /// stage followed by the 8-byte signal update on the same target.
    fn put_signal_stages(&self, plan: &TransferPlan, pe: usize) -> [ChainStage; 2] {
        let reachable = self.ipc.lookup(pe).is_some();
        [
            ChainStage { reachable, loc: plan.loc, bytes: plan.bytes },
            ChainStage { reachable, loc: plan.loc, bytes: 8 },
        ]
    }

    /// Roll a partially staged chain back: return every slab claim taken
    /// so far (the arena rewinds once the count drops to zero — nothing
    /// was submitted, so nothing reads the staged bytes) and the lane
    /// backlog reserved for it, then count the abandon.
    fn chain_unstage(&self, claims: usize, lanes: Lanes, reserved: &[(usize, u64)]) {
        for _ in 0..claims {
            self.slab.release();
        }
        for &(lane, bytes) in reserved {
            self.lane_release(lanes, lane, bytes);
        }
        Metrics::add(&self.rt.metrics.chain_flushed_unfusable, 1);
    }

    /// Try to execute a planned put-signal as a **fused triggered chain**
    /// (ISSUE 10): payload chunks at stage 0 and the signal AMO at
    /// stage 1, submitted as ONE `Batch` doorbell. The proxy holds the
    /// signal descriptor in its pending-trigger table until every chunk's
    /// engine/rail execution completes, so the paper's "put; fence;
    /// signal" ordering moves off the host without the forced stream
    /// flush the unfused path pays. Returns `false` (nothing happened)
    /// when chains are disabled, the chain cannot fuse (depth cap, slab
    /// pressure, or the model prices sequential submission cheaper), or
    /// the route is `LoadStore` — the caller then takes the classic path.
    pub(crate) fn exec_put_signal_chain(
        &self,
        plan: &TransferPlan,
        pe: usize,
        dst_off: usize,
        src: &[u8],
        sig_off: usize,
        signal: u64,
        sig_add: bool,
    ) -> bool {
        let ccfg = self.rt.config.chain;
        if !ccfg.enable || plan.route == Route::LoadStore {
            return false;
        }
        let layout = if plan.chunks() > 1 {
            self.plan_layout(plan)
        } else {
            vec![(0usize, 0usize, plan.bytes)]
        };
        let depth = layout.len() + 1; // chunks + the signal stage
        let cap = ccfg.max_depth.min(self.stream.max_depth());
        if depth > cap || !self.rt.xfer.chain_fuse_wins(&self.put_signal_stages(plan, pe)) {
            Metrics::add(&self.rt.metrics.chain_flushed_unfusable, 1);
            return false;
        }
        // Clean slate: the chain must be alone in its batch so NACK-mask
        // entry indices line up with chain stages, and a drained stream
        // gives the slab its full capacity for the payload stage.
        self.stream_quiet_drain();
        let (lanes, slots) = self.lanes_for(plan);
        let total = layout.len();
        let mut entries: Vec<(BatchDescriptor, usize)> = Vec::with_capacity(depth);
        let mut reserved: Vec<(usize, u64)> = Vec::with_capacity(total);
        for (idx, off, len) in layout {
            let Some(slab_off) = self.stream_stage_payload_uncharged(&src[off..off + len])
            else {
                // Slab cannot hold the fused payload: abandon the fusion
                // (the raw-pointer tail of the classic path cannot ride a
                // triggered batch) and let the caller flush sequentially.
                self.chain_unstage(entries.len(), lanes, &reserved);
                return false;
            };
            let lane = slots[idx % slots.len()];
            let desc = BatchDescriptor::put(pe, dst_off + off, slab_off, len)
                .with_standard_cl(self.standard_cl_for(len))
                .with_chunk(idx as u32, total as u32, lane as u8)
                .with_transfer_bytes(plan.bytes as u64)
                .with_stage(0);
            entries.push((desc, 1));
            self.lane_reserve(lanes, lane, len as u64);
            reserved.push((lane, len as u64));
        }
        let kind = if sig_add { AmoKind::Add } else { AmoKind::Set };
        let sig = BatchDescriptor::amo(
            pe,
            sig_off,
            crate::ishmem::types::TypeTag::U64 as u8,
            kind as u8,
            signal,
            0,
        )
        .with_stage(1);
        entries.push((sig, 0));
        self.track.note_chain_links((depth - 1) as u64);
        self.stream_post_chain(entries);
        // One striped charge covers the payload pipeline; the signal is a
        // pipelined fire-and-forget atomic riding the drained doorbell.
        self.charge_chunked(plan, pe, total);
        self.clock.advance(self.rt.cost.pipelined_atomics_ns(1));
        let (path, loc) = match plan.route {
            Route::Nic => (PathIdx::Nic, Locality::Remote),
            _ => (PathIdx::CopyEngine, plan.loc),
        };
        self.rt.metrics.add_path_bytes(path, loc, 8);
        for (lane, bytes) in reserved {
            self.lane_release(lanes, lane, bytes);
        }
        true
    }

    /// Try to execute a signal-gated get as a fused triggered chain: a
    /// `WaitSignal` gate at stage 0 (proxy-side wait until the signal
    /// word at `sig_off` on `sig_pe` reaches `target`) releasing get
    /// chunks at stage 1, one doorbell for the whole dependency. The
    /// initiator blocks in the chain's retiring flush while the proxy
    /// parks the chain; a producer's put-signal un-parks it. Returns
    /// `false` when the chain cannot fuse — the caller then waits on the
    /// signal word host-side and issues a plain get.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn exec_signal_get_chain(
        &self,
        plan: &TransferPlan,
        sig_pe: usize,
        sig_off: usize,
        target: u64,
        pe: usize,
        src_off: usize,
        dst: &mut [u8],
    ) -> bool {
        let ccfg = self.rt.config.chain;
        if !ccfg.enable || plan.route == Route::LoadStore {
            return false;
        }
        let layout = if plan.chunks() > 1 {
            self.plan_layout(plan)
        } else {
            vec![(0usize, 0usize, plan.bytes)]
        };
        let depth = layout.len() + 1; // the gate + get chunks
        let cap = ccfg.max_depth.min(self.stream.max_depth());
        let stages = [
            ChainStage {
                reachable: self.ipc.lookup(sig_pe).is_some(),
                loc: self.loc_of(sig_pe),
                bytes: 8,
            },
            ChainStage {
                reachable: self.ipc.lookup(pe).is_some(),
                loc: plan.loc,
                bytes: plan.bytes,
            },
        ];
        if depth > cap || !self.rt.xfer.chain_fuse_wins(&stages) {
            Metrics::add(&self.rt.metrics.chain_flushed_unfusable, 1);
            return false;
        }
        // Drained stream: every get chunk's slab claim must live together
        // until the one chain batch retires (no window recycling), so the
        // chain needs the whole arena — and must be alone in its batch.
        self.stream_quiet_drain();
        let (lanes, slots) = self.lanes_for(plan);
        let total = layout.len();
        let mut entries: Vec<(BatchDescriptor, usize)> = Vec::with_capacity(depth);
        entries.push((BatchDescriptor::wait_signal(sig_pe, sig_off, target).with_stage(0), 0));
        let mut reserved: Vec<(usize, u64)> = Vec::with_capacity(total);
        let mut window: Vec<(usize, usize, usize)> = Vec::with_capacity(total); // (slab, dst, len)
        for (idx, off, len) in layout {
            let Some(slab_off) = self.stream_slab_try_alloc(len) else {
                // The whole result set cannot sit in the slab at once:
                // abandon the fusion, host-side wait + plain get instead.
                self.chain_unstage(window.len(), lanes, &reserved);
                return false;
            };
            let lane = slots[idx % slots.len()];
            let desc = BatchDescriptor::get(pe, slab_off, src_off + off, len)
                .with_standard_cl(self.standard_cl_for(len))
                .with_chunk(idx as u32, total as u32, lane as u8)
                .with_transfer_bytes(plan.bytes as u64)
                .with_stage(1);
            entries.push((desc, 1));
            self.lane_reserve(lanes, lane, len as u64);
            reserved.push((lane, len as u64));
            window.push((slab_off, off, len));
        }
        self.track.note_chain_links((depth - 1) as u64);
        self.stream_post_chain(entries);
        // The proxy landed the gated results in the slab; copy them out
        // before anything else can rewind the arena over them (claims
        // were released at retire, but this PE is single-threaded).
        for &(slab_off, doff, len) in &window {
            self.rt
                .heaps
                .heap(self.pe())
                .read(slab_off, &mut dst[doff..doff + len]);
        }
        self.charge_chunked(plan, pe, total);
        self.clock.advance(self.rt.cost.staging_copy_ns(plan.bytes));
        for (lane, bytes) in reserved {
            self.lane_release(lanes, lane, bytes);
        }
        true
    }

    // ------------------------------------------------- AMO / inline ops --

    /// Proxied atomic. Fetching AMOs cannot batch (the result gates the
    /// caller), so they ship their own `Amo` ring message behind a
    /// pending-stream flush and block on the reply. Fire-and-forget kinds
    /// join the batched command stream instead (the descriptor codec
    /// carries them): one `Batch` doorbell amortizes a whole burst, the
    /// stream keeps per-PE FIFO order, and `quiet`'s stream drain proves
    /// delivery. Returns the fetched old value (0 for non-fetching kinds).
    pub(crate) fn proxied_amo(
        &self,
        pe: usize,
        dst_off: usize,
        dtype: u8,
        kind: AmoKind,
        operand: u64,
        comparand: u64,
        fetching: bool,
    ) -> u64 {
        if fetching {
            let mut m = Message::nop();
            m.op = RingOp::Amo as u8;
            m.dtype = dtype;
            m.flags = kind as u8 as u16;
            m.pe = pe as u32;
            m.dst_off = dst_off as u64;
            m.inline_val = operand;
            m.inline_val2 = comparand;
            let old = self.proxied_blocking(m);
            self.clock
                .advance(self.rt.cost.fetch_atomic_ns(Locality::Remote));
            old
        } else {
            let desc =
                BatchDescriptor::amo(pe, dst_off, dtype, kind as u8, operand, comparand);
            self.stream_append(desc, 0);
            // The descriptor write is charged by the append; the doorbell
            // is one amortized ring post at flush time — the PR-2 win,
            // extended to AMOs.
            0
        }
    }

    /// Proxied inline scalar put (≤ 8 bytes ride inside the message):
    /// locally complete as soon as the message is posted.
    pub(crate) fn proxied_put_inline(
        &self,
        pe: usize,
        dst_off: usize,
        dtype: u8,
        len: usize,
        raw: u64,
    ) {
        let mut m = Message::nop();
        m.op = RingOp::PutInline as u8;
        m.dtype = dtype;
        m.pe = pe as u32;
        m.dst_off = dst_off as u64;
        m.len = len as u64;
        m.inline_val = raw;
        self.proxied_ff(m);
        self.clock.advance(self.rt.cost.ring_post_ns());
        self.rt
            .metrics
            .add_path_bytes(PathIdx::Nic, Locality::Remote, len as u64);
    }
}
