//! Closed-loop cost-model calibration (ISSUE 5).
//!
//! The planner scores candidate paths against hardware constants that are
//! config defaults, not measured silicon — and PR 4's wall-vs-model
//! ledgers measure exactly how wrong they are, per (path, size-class).
//! The [`Calibrator`] closes that loop: it consumes the proxy's
//! per-(path, lane, size-class) wall-time observations, *inverts* the
//! cost-model formula on each observation to get the implied value of a
//! learnable constant, EMA-refines the implication streams, and writes
//! refined values into the shared [`ModelParams`] store — so the stripe
//! planner, the rail planner, and the per-op CL policy all re-score
//! against observed hardware behavior instead of config defaults.
//!
//! ## Observation → parameter attribution
//!
//! Observations arrive at *chunk* granularity (one command-list dispatch
//! on one engine, or one RDMA injection on one rail), so every
//! observation is a width-1 sample — the cleanest thing to invert:
//!
//! * **small classes** (≤ 64 KiB, where `T ≈ startup`): solve
//!   `startup = T − bytes / lane_bw` for the startup term of the
//!   observed flavor (`startup_immediate_ns` / `startup_standard_ns` /
//!   `rail_startup_ns`);
//! * **large classes** (> 256 KiB, where `T ≈ bytes / lane_bw`): solve
//!   `frac = bytes / ((T − startup) · roofline)` for the bandwidth
//!   fraction (`single_engine_frac` / `rail_bw_frac`);
//! * the **middle class** feeds only the residual ledgers (its signal is
//!   ambiguous between the two terms).
//!
//! The two inversions use each other's current learned value, so they
//! converge jointly (the startup bias shrinks as the fraction converges
//! and vice versa — property-tested against planted ground truth).
//!
//! The **CL boundary** is the third learned quantity: per size class the
//! calibrator tracks the observed per-byte cost of immediate-flagged vs
//! standard-flagged engine dispatches, estimates the crossover class
//! where standard starts winning, and nudges `cl_immediate_max_bytes`
//! toward that boundary — mirroring how `Adaptive` learns the cutover.
//!
//! ## Safety rails
//!
//! * `calib.enable = false` (the default) makes every observation a no-op:
//!   [`ModelParams`] never moves, its version stays 0, and all plan
//!   estimates are bit-identical to the pre-calibration code (tested in
//!   `sim::cost` and here).
//! * `calib.min_samples` gates the first apply of each quantity.
//! * `calib.clamp_frac` bounds the multiplicative drift of every learned
//!   value from its configured seed (wall clocks on a foreign substrate
//!   can be wildly off; the clamp keeps a garbage stream from driving the
//!   model into nonsense). Fractions are additionally capped at 1.0.
//! * Updates apply only when the learned value moved ≥ 1% from the live
//!   value, so the `ModelParams` version — the staleness token plans and
//!   adaptive cells carry — bumps on *material* recalibrations, not on
//!   every EMA tick.
//!
//! Size classes are the **shared** service-delta classes
//! ([`SERVICE_SIZE_BOUNDS`]) — the calibrator's buckets and the
//! `figure service-delta` rows can never drift apart.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::{
    service_size_bucket, service_size_label, SERVICE_SIZE_BOUNDS, SERVICE_SIZE_BUCKETS,
};
use crate::sim::fault::{FaultAction, FaultPlane};
use crate::sim::topology::Locality;
use crate::sim::CostModel;
use crate::util::json::Json;

/// Calibration knobs (`IshmemConfig::calib`).
#[derive(Clone, Debug)]
pub struct CalibConfig {
    /// Master switch. Off (the default) = today's behavior bit-for-bit:
    /// observations are dropped and `ModelParams` never moves.
    pub enable: bool,
    /// EMA weight of one implied-value observation (0 < α ≤ 1).
    pub ema_alpha: f64,
    /// Observations a quantity needs before its first apply.
    pub min_samples: u64,
    /// Maximum multiplicative drift of a learned value from its
    /// configured seed: live ∈ [seed / clamp, seed · clamp] (≥ 1).
    pub clamp_frac: f64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            enable: false,
            ema_alpha: 0.25,
            min_samples: 32,
            clamp_frac: 4.0,
        }
    }
}

/// Learned-quantity slots.
const Q_ENGINE_FRAC: usize = 0;
const Q_STARTUP_IMM: usize = 1;
const Q_STARTUP_STD: usize = 2;
const Q_RAIL_FRAC: usize = 3;
const Q_RAIL_STARTUP: usize = 4;
const Q_CL_BOUNDARY: usize = 5;
const QUANTITIES: usize = 6;

const QUANTITY_NAMES: [&str; QUANTITIES] = [
    "ce.single_engine_frac",
    "ce.startup_immediate_ns",
    "ce.startup_standard_ns",
    "nic.rail_bw_frac",
    "nic.rail_startup_ns",
    "cl_immediate_max_bytes",
];

/// Residual-ledger rows: the lane flavors whose predictions differ.
const PATH_ENGINE_IMM: usize = 0;
const PATH_ENGINE_STD: usize = 1;
const PATH_RAIL: usize = 2;
const CALIB_PATHS: usize = 3;
const PATH_NAMES: [&str; CALIB_PATHS] = ["engine-imm", "engine-std", "rail"];

/// Classes at or below this index (≤ 64 KiB) refine startup terms.
const STARTUP_CLASS_MAX: usize = 1;
/// Classes at or above this index (> 256 KiB) refine bandwidth fractions.
const FRAC_CLASS_MIN: usize = 3;
/// Minimum relative move of a learned value before it applies to
/// `ModelParams` (keeps the version counter on material changes).
const APPLY_REL_EPS: f64 = 0.01;

/// EMA of a stream of implied parameter values.
#[derive(Clone, Copy, Debug, Default)]
struct Learn {
    ema: f64,
    samples: u64,
}

impl Learn {
    fn push(&mut self, alpha: f64, v: f64) {
        if self.samples == 0 {
            self.ema = v;
        } else {
            self.ema = (1.0 - alpha) * self.ema + alpha * v;
        }
        self.samples += 1;
    }
}

/// Per-(path, size-class) observation ledger (the calibration twin of the
/// metrics service-delta tables — same class geometry by construction).
#[derive(Clone, Copy, Debug, Default)]
struct ClassLedger {
    samples: u64,
    wall_ns: f64,
    bytes: u64,
}

/// Per-(node, rail) detector evidence: the implied bandwidth-fraction
/// EMA of that one rail, plus quarantine bookkeeping (ISSUE 8).
#[derive(Clone, Copy, Debug, Default)]
struct RailHealth {
    frac: Learn,
    quarantined: bool,
    /// Node-observation clock reading at quarantine time (the probation
    /// timer compares against it).
    quarantined_at_obs: u64,
}

#[derive(Debug, Default)]
struct CalibState {
    learn: [Learn; QUANTITIES],
    /// Calibrator-as-detector evidence, one row per observed (node, rail).
    rail_health: HashMap<(usize, usize), RailHealth>,
    /// Total rail observations per node — the probation clock for
    /// quarantined-rail revival probes.
    node_obs: HashMap<usize, u64>,
    ledger: [[ClassLedger; SERVICE_SIZE_BUCKETS]; CALIB_PATHS],
    /// Observed per-byte cost EMA per (CL flavor, class): the crossover
    /// evidence for the learned CL boundary. [0] = immediate, [1] =
    /// standard.
    cl_cost: [[Learn; SERVICE_SIZE_BUCKETS]; 2],
    /// Observations since the last apply attempt — the apply pass (six
    /// clamp/target computations + two ModelParams reads) runs once per
    /// `min_samples` observations, not per serviced descriptor.
    obs_since_apply: u64,
    /// `refine_cl_boundary` calls since the last boundary nudge — the
    /// proxy invokes it once per serviced batch, but the nudge (and its
    /// apply pass) runs once per `min_samples` calls so boundary motion
    /// paces with evidence, not doorbell frequency.
    cl_refine_ticks: u64,
}

/// The closed-loop calibrator: proxy observations in, refined
/// [`ModelParams`] out. One per machine, shared with the proxy threads.
#[derive(Debug)]
pub struct Calibrator {
    cost: Arc<CostModel>,
    cfg: CalibConfig,
    /// Attached fault plane (ISSUE 8): present and enabled, rail
    /// observations double as failure-detector evidence. Set once at
    /// machine construction; `None` keeps the detector inert.
    fault: Mutex<Option<Arc<FaultPlane>>>,
    state: Mutex<CalibState>,
}

impl Calibrator {
    pub fn new(cost: Arc<CostModel>, cfg: CalibConfig) -> Self {
        Calibrator {
            cost,
            cfg,
            fault: Mutex::new(None),
            state: Mutex::new(CalibState::default()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enable
    }

    pub fn config(&self) -> &CalibConfig {
        &self.cfg
    }

    /// Attach the fault plane (machine construction). With an *enabled*
    /// plane attached, [`Self::observe_rail`] runs the detector; without
    /// one, rail observations only feed the learners — exactly the
    /// pre-fault behavior.
    pub fn set_fault_plane(&self, plane: Arc<FaultPlane>) {
        *self.fault.lock().unwrap() = Some(plane);
    }

    // ------------------------------------------------------ observations --

    /// One observed intra-node engine dispatch: `bytes` moved on one
    /// engine lane under the given CL flavor in `wall_ns` wall-clock
    /// nanoseconds (the proxy tags each serviced entry / staged-list
    /// execute with its lane and elapsed time).
    pub fn observe_engine(&self, loc: Locality, bytes: usize, immediate_cl: bool, wall_ns: f64) {
        if !self.cfg.enable || bytes == 0 || !(wall_ns > 0.0) || loc == Locality::Remote {
            return;
        }
        let roofline = self.cost.params.ce.path_bw_gbs(&self.cost.params.xe, loc);
        if roofline <= 0.0 {
            return;
        }
        let live = self.cost.model.get();
        let class = service_size_bucket(bytes as u64);
        let alpha = self.cfg.ema_alpha;
        let do_apply = {
            let mut st = self.state.lock().unwrap();
            let row = if immediate_cl { PATH_ENGINE_IMM } else { PATH_ENGINE_STD };
            let l = &mut st.ledger[row][class];
            l.samples += 1;
            l.wall_ns += wall_ns;
            l.bytes += bytes as u64;
            let lane_bw = roofline * live.single_engine_frac.clamp(0.01, 1.0);
            if class <= STARTUP_CLASS_MAX {
                // T ≈ startup + bytes/lane_bw ⇒ startup = T − data term.
                let implied = wall_ns - bytes as f64 / lane_bw;
                if implied > 0.0 {
                    let q = if immediate_cl { Q_STARTUP_IMM } else { Q_STARTUP_STD };
                    st.learn[q].push(alpha, implied);
                }
            } else if class >= FRAC_CLASS_MIN {
                // T ≈ startup + bytes/(frac·roofline) ⇒ solve for frac.
                let startup = if immediate_cl {
                    live.startup_immediate_ns
                } else {
                    live.startup_standard_ns
                };
                let data_ns = wall_ns - startup;
                if data_ns > 0.0 {
                    let implied = (bytes as f64 / (data_ns * roofline)).clamp(1e-3, 1.0);
                    st.learn[Q_ENGINE_FRAC].push(alpha, implied);
                }
            }
            self.tick_apply(&mut st)
        };
        if do_apply {
            self.maybe_apply();
        }
    }

    /// One *comparable* CL-flavor cost observation for the learned
    /// boundary: `chunk_bytes` is the per-descriptor payload size the
    /// boundary decision applies to, `per_byte_ns` the **total** per-byte
    /// cost of serving it under that flavor — for standard lists the
    /// caller must fold the append cost in with the amortized execute
    /// (append + execute over the list's bytes), for immediate lists the
    /// inline service time. This is deliberately separate from
    /// [`Self::observe_engine`]: the lane learners want pure engine time
    /// (a staged list's append is not engine time), but comparing flavors
    /// on engine time alone would make standard lists look cheaper than
    /// they are and drive the boundary toward zero.
    pub fn observe_cl_flavor(&self, chunk_bytes: usize, immediate_cl: bool, per_byte_ns: f64) {
        if !self.cfg.enable || chunk_bytes == 0 || !(per_byte_ns > 0.0) {
            return;
        }
        let class = service_size_bucket(chunk_bytes as u64);
        let mut st = self.state.lock().unwrap();
        st.cl_cost[if immediate_cl { 0 } else { 1 }][class].push(self.cfg.ema_alpha, per_byte_ns);
    }

    /// One observed inter-node rail injection: `bytes` on NIC rail `rail`
    /// of `node` in `wall_ns` wall-clock nanoseconds.
    ///
    /// Doubles as the **failure detector** (ISSUE 8): per-(node, rail)
    /// implied bandwidth fractions are EMA-tracked, and — when an enabled
    /// [`FaultPlane`] is attached — a rail collapsing below
    /// `fault.detect_frac` × the mean of its live peers is quarantined
    /// (killed in the cost model: the health generation bumps, plan
    /// caches flush, and new plans re-stripe onto the survivors), then
    /// probationally revived `fault.probe_after` node observations later.
    /// Returns the applied health transition, if any, so the caller can
    /// count it into its metrics.
    pub fn observe_rail(
        &self,
        node: usize,
        rail: usize,
        bytes: usize,
        wall_ns: f64,
    ) -> Option<FaultAction> {
        if !self.cfg.enable || bytes == 0 || !(wall_ns > 0.0) {
            return None;
        }
        let roofline = self.cost.params.nic.bw_gbs;
        if roofline <= 0.0 {
            return None;
        }
        let live = self.cost.model.get();
        let class = service_size_bucket(bytes as u64);
        let alpha = self.cfg.ema_alpha;
        let plane = self.fault.lock().unwrap().clone();
        let (do_apply, action) = {
            let mut st = self.state.lock().unwrap();
            let l = &mut st.ledger[PATH_RAIL][class];
            l.samples += 1;
            l.wall_ns += wall_ns;
            l.bytes += bytes as u64;
            let lane_bw = roofline * live.rail_bw_frac.clamp(0.01, 1.0);
            let mut implied_frac = None;
            if class <= STARTUP_CLASS_MAX {
                let implied = wall_ns - bytes as f64 / lane_bw;
                if implied > 0.0 {
                    st.learn[Q_RAIL_STARTUP].push(alpha, implied);
                }
            } else if class >= FRAC_CLASS_MIN {
                let data_ns = wall_ns - live.rail_startup_ns;
                if data_ns > 0.0 {
                    let implied = (bytes as f64 / (data_ns * roofline)).clamp(1e-3, 1.0);
                    st.learn[Q_RAIL_FRAC].push(alpha, implied);
                    implied_frac = Some(implied);
                }
            }
            let action = match &plane {
                Some(p) if p.enabled() => {
                    self.rail_health_step(&mut st, p, node, rail, implied_frac)
                }
                _ => None,
            };
            (self.tick_apply(&mut st), action)
        };
        if do_apply {
            self.maybe_apply();
        }
        action
    }

    /// One detector step (state lock held): advance the node's probation
    /// clock, absorb the suspect's fresh implied fraction, fire a due
    /// probation revival, then judge the suspect against its live peers.
    /// At most one health transition per observation.
    fn rail_health_step(
        &self,
        st: &mut CalibState,
        plane: &Arc<FaultPlane>,
        node: usize,
        rail: usize,
        implied_frac: Option<f64>,
    ) -> Option<FaultAction> {
        let fcfg = plane.config();
        let clock = st.node_obs.entry(node).or_insert(0);
        *clock += 1;
        let now = *clock;
        if let Some(f) = implied_frac {
            let h = st.rail_health.entry((node, rail)).or_default();
            if !h.quarantined {
                h.frac.push(self.cfg.ema_alpha, f);
            }
        }
        // Probation: revive the lowest-indexed quarantined rail on this
        // node whose wait has reached `probe_after`. Its evidence resets,
        // so re-judgment waits for fresh samples — a rail that is still
        // collapsed drifts back under the threshold and is re-killed.
        let due = st
            .rail_health
            .iter()
            .filter(|((n, _), h)| {
                *n == node
                    && h.quarantined
                    && now.saturating_sub(h.quarantined_at_obs) >= fcfg.probe_after
            })
            .map(|((_, r), _)| *r)
            .min();
        if let Some(r) = due {
            let h = st.rail_health.get_mut(&(node, r)).unwrap();
            h.quarantined = false;
            h.frac = Learn::default();
            if let Some(a) = plane.apply(FaultAction::ReviveRail { node, rail: r }) {
                return Some(a);
            }
        }
        // Judgment fires only on fresh suspect evidence.
        implied_frac?;
        let suspect = *st.rail_health.get(&(node, rail))?;
        if suspect.quarantined || suspect.frac.samples < fcfg.detect_min_samples {
            return None;
        }
        let peers: Vec<f64> = st
            .rail_health
            .iter()
            .filter(|((n, r), h)| {
                *n == node
                    && *r != rail
                    && !h.quarantined
                    && h.frac.samples >= fcfg.detect_min_samples
            })
            .map(|(_, h)| h.frac.ema)
            .collect();
        if peers.is_empty() {
            return None;
        }
        let peer_mean = peers.iter().sum::<f64>() / peers.len() as f64;
        if suspect.frac.ema < fcfg.detect_frac * peer_mean {
            if let Some(a) = plane.apply(FaultAction::KillRail { node, rail }) {
                let h = st.rail_health.get_mut(&(node, rail)).unwrap();
                h.quarantined = true;
                h.quarantined_at_obs = now;
                return Some(a);
            }
        }
        None
    }

    /// Reliability-layer escalation hook (ISSUE 9): the proxy's strike
    /// ledger hands a repeat-offender rail here once it crosses
    /// `retry.escalate_strikes`. The rail is killed on the fault plane
    /// *through* the detector's quarantine state — exactly as if the
    /// implied-bandwidth judge had condemned it — so the normal
    /// `fault.probe_after` probation revival applies while calibration
    /// feeds observations. With `calib.enable` off the node's observation
    /// clock never advances, so an escalated rail stays down until a
    /// scripted `ReviveRail` event (documented in the xfer README).
    pub fn escalate_rail(&self, node: usize, rail: usize) -> Option<FaultAction> {
        let plane = self.fault.lock().unwrap().clone()?;
        let mut st = self.state.lock().unwrap();
        let now = st.node_obs.get(&node).copied().unwrap_or(0);
        let a = plane.apply(FaultAction::KillRail { node, rail })?;
        let h = st.rail_health.entry((node, rail)).or_default();
        h.quarantined = true;
        h.quarantined_at_obs = now;
        Some(a)
    }

    /// Count one observation toward the periodic apply pass; returns true
    /// once per `min_samples` observations.
    fn tick_apply(&self, st: &mut CalibState) -> bool {
        st.obs_since_apply += 1;
        if st.obs_since_apply >= self.cfg.min_samples.max(1) {
            st.obs_since_apply = 0;
            true
        } else {
            false
        }
    }

    // ------------------------------------------------------------ apply --

    /// Push sufficiently-sampled learned values into the shared
    /// `ModelParams`, clamped around the configured seed; the store bumps
    /// its version (aging out plans and adaptive cells) only when a value
    /// moved materially.
    fn maybe_apply(&self) {
        let seed = self.cost.model.seed();
        let live = self.cost.model.get();
        let mut target = live;
        {
            let st = self.state.lock().unwrap();
            let clamp = |seed_v: f64, v: f64| {
                v.clamp(seed_v / self.cfg.clamp_frac, seed_v * self.cfg.clamp_frac)
            };
            let ready = |q: usize| st.learn[q].samples >= self.cfg.min_samples;
            if ready(Q_ENGINE_FRAC) {
                target.single_engine_frac =
                    clamp(seed.single_engine_frac, st.learn[Q_ENGINE_FRAC].ema).min(1.0);
            }
            if ready(Q_STARTUP_IMM) {
                target.startup_immediate_ns =
                    clamp(seed.startup_immediate_ns, st.learn[Q_STARTUP_IMM].ema);
            }
            if ready(Q_STARTUP_STD) {
                target.startup_standard_ns =
                    clamp(seed.startup_standard_ns, st.learn[Q_STARTUP_STD].ema);
            }
            if ready(Q_RAIL_FRAC) {
                target.rail_bw_frac =
                    clamp(seed.rail_bw_frac, st.learn[Q_RAIL_FRAC].ema).min(1.0);
            }
            if ready(Q_RAIL_STARTUP) {
                target.rail_startup_ns =
                    clamp(seed.rail_startup_ns, st.learn[Q_RAIL_STARTUP].ema);
            }
            // The boundary learner is gated upstream (per-flavor-class
            // min_samples evidence + the refine tick pacing), so it only
            // needs the seed push plus one nudge here — re-gating it at
            // min_samples would starve it under the paced nudges.
            if st.learn[Q_CL_BOUNDARY].samples >= 2 {
                // The CL boundary is an integer byte count; clamp around
                // the configured seed like every other quantity. A seed
                // of usize::MAX (no machine config) saturates and never
                // moves — there is nothing to learn against.
                if seed.cl_immediate_max_bytes != usize::MAX {
                    let s = seed.cl_immediate_max_bytes as f64;
                    target.cl_immediate_max_bytes =
                        clamp(s, st.learn[Q_CL_BOUNDARY].ema).round() as usize;
                }
            }
        }
        // Material-change gate: apply only fields that moved ≥ 1% — and
        // merge them **field by field** inside the model's own write lock,
        // never `*l = snapshot`: a wholesale overwrite would revert a
        // concurrent proxy thread's freshly-applied field to the stale
        // value this thread read before the lock.
        let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
        let changed = |cur: f64, tgt: f64| -> Option<f64> {
            (rel(cur, tgt) >= APPLY_REL_EPS).then_some(tgt)
        };
        let engine_frac = changed(live.single_engine_frac, target.single_engine_frac);
        let s_imm = changed(live.startup_immediate_ns, target.startup_immediate_ns);
        let s_std = changed(live.startup_standard_ns, target.startup_standard_ns);
        let rail_frac = changed(live.rail_bw_frac, target.rail_bw_frac);
        let rail_startup = changed(live.rail_startup_ns, target.rail_startup_ns);
        let cl = (live.cl_immediate_max_bytes != target.cl_immediate_max_bytes
            && rel(
                live.cl_immediate_max_bytes as f64,
                target.cl_immediate_max_bytes as f64,
            ) >= APPLY_REL_EPS)
            .then_some(target.cl_immediate_max_bytes);
        if [engine_frac, s_imm, s_std, rail_frac, rail_startup].iter().any(Option::is_some)
            || cl.is_some()
        {
            self.cost.model.update(|l| {
                if let Some(v) = engine_frac {
                    l.single_engine_frac = v;
                }
                if let Some(v) = s_imm {
                    l.startup_immediate_ns = v;
                }
                if let Some(v) = s_std {
                    l.startup_standard_ns = v;
                }
                if let Some(v) = rail_frac {
                    l.rail_bw_frac = v;
                }
                if let Some(v) = rail_startup {
                    l.rail_startup_ns = v;
                }
                if let Some(v) = cl {
                    l.cl_immediate_max_bytes = v;
                }
            });
        }
    }

    /// Feed the CL-boundary learner from the per-class flavor costs: the
    /// crossover is the floor of the smallest class where the standard
    /// flavor's observed per-byte cost is at least as cheap as the
    /// immediate flavor's. Called from `maybe_apply` indirectly via the
    /// crossover estimate below — exposed for the boundary nudge.
    fn crossover_target_bytes(&self, st: &CalibState) -> Option<f64> {
        let min = self.cfg.min_samples;
        let mut saw_comparable = false;
        for c in 0..SERVICE_SIZE_BUCKETS {
            let imm = st.cl_cost[0][c];
            let std = st.cl_cost[1][c];
            if imm.samples < min || std.samples < min {
                continue;
            }
            saw_comparable = true;
            if std.ema <= imm.ema {
                // Standard wins from this class up: the boundary is the
                // class floor (its predecessor's upper bound).
                return Some(if c == 0 {
                    1.0
                } else {
                    SERVICE_SIZE_BOUNDS[c - 1] as f64
                });
            }
        }
        if saw_comparable {
            // Immediate won every comparable class: push the boundary to
            // the top of the classed range (the clamp still anchors it).
            return Some(*SERVICE_SIZE_BOUNDS.last().unwrap() as f64 * 4.0);
        }
        // Disjoint-evidence fallback: on live traffic the boundary itself
        // decides each entry's flavor, so no class ever accumulates both
        // flavors — same-class comparison alone would leave the boundary
        // structurally inert. Instead compare the *frontier*: the most
        // expensive sampled immediate class against the cheapest sampled
        // standard class. Immediate still cheaper per byte at its frontier
        // ⇒ grow the immediate window one class bound; standard cheaper ⇒
        // concede the top immediate class. The EMA nudge plus the seed
        // clamp turn this into a bounded hill-climb.
        let hi_imm = (0..SERVICE_SIZE_BUCKETS).rev().find(|&c| st.cl_cost[0][c].samples >= min);
        let lo_std = (0..SERVICE_SIZE_BUCKETS).find(|&c| st.cl_cost[1][c].samples >= min);
        match (hi_imm, lo_std) {
            (Some(ci), Some(cs)) if ci < cs => {
                Some(if st.cl_cost[0][ci].ema <= st.cl_cost[1][cs].ema {
                    if cs < SERVICE_SIZE_BOUNDS.len() {
                        SERVICE_SIZE_BOUNDS[cs] as f64
                    } else {
                        *SERVICE_SIZE_BOUNDS.last().unwrap() as f64 * 4.0
                    }
                } else if ci == 0 {
                    1.0
                } else {
                    SERVICE_SIZE_BOUNDS[ci - 1] as f64
                })
            }
            _ => None,
        }
    }

    /// Run one CL-boundary refinement step from the accumulated flavor
    /// costs (the per-observation hooks feed `cl_cost`; this nudges the
    /// learned boundary toward the estimated crossover and applies it).
    pub fn refine_cl_boundary(&self) {
        if !self.cfg.enable {
            return;
        }
        let alpha = self.cfg.ema_alpha;
        {
            let mut st = self.state.lock().unwrap();
            st.cl_refine_ticks += 1;
            if st.cl_refine_ticks < self.cfg.min_samples.max(1) {
                return;
            }
            st.cl_refine_ticks = 0;
            let Some(target) = self.crossover_target_bytes(&st) else {
                return;
            };
            let seeded = st.learn[Q_CL_BOUNDARY].samples > 0;
            if !seeded {
                // Start the nudge from the currently-configured boundary,
                // not from zero.
                let cur = self.cost.model.get().cl_immediate_max_bytes;
                if cur != usize::MAX {
                    st.learn[Q_CL_BOUNDARY].push(1.0, cur as f64);
                }
            }
            st.learn[Q_CL_BOUNDARY].push(alpha, target);
        }
        self.maybe_apply();
    }

    // -------------------------------------------------------- prediction --

    /// Current-model prediction of one engine-lane dispatch (what the
    /// residual ledgers compare observed wall times against).
    pub fn predict_engine_ns(&self, loc: Locality, bytes: usize, immediate_cl: bool) -> f64 {
        let live = self.cost.model.get();
        let roofline = self.cost.params.ce.path_bw_gbs(&self.cost.params.xe, loc);
        let startup = if immediate_cl {
            live.startup_immediate_ns
        } else {
            live.startup_standard_ns
        };
        startup + bytes as f64 / (roofline * live.single_engine_frac.clamp(0.01, 1.0))
    }

    /// Current-model prediction of one rail injection.
    pub fn predict_rail_ns(&self, bytes: usize) -> f64 {
        let live = self.cost.model.get();
        live.rail_startup_ns
            + bytes as f64 / (self.cost.params.nic.bw_gbs * live.rail_bw_frac.clamp(0.01, 1.0))
    }

    // ---------------------------------------------------------- snapshot --

    /// Full calibration snapshot: learned vs configured params with sample
    /// counts, and per-(path, size-class) residuals of observed wall time
    /// against the *current* learned model (so the residuals shrink as the
    /// model converges — the `figure calibration` convergence signal).
    pub fn snapshot(&self) -> CalibrationSnapshot {
        let st = self.state.lock().unwrap();
        let seed = self.cost.model.seed();
        let live = self.cost.model.get();
        let seed_vals = [
            seed.single_engine_frac,
            seed.startup_immediate_ns,
            seed.startup_standard_ns,
            seed.rail_bw_frac,
            seed.rail_startup_ns,
            seed.cl_immediate_max_bytes as f64,
        ];
        let live_vals = [
            live.single_engine_frac,
            live.startup_immediate_ns,
            live.startup_standard_ns,
            live.rail_bw_frac,
            live.rail_startup_ns,
            live.cl_immediate_max_bytes as f64,
        ];
        let params = (0..QUANTITIES)
            .map(|q| ParamRow {
                name: QUANTITY_NAMES[q],
                configured: seed_vals[q],
                learned: live_vals[q],
                samples: st.learn[q].samples,
            })
            .collect();
        let mut classes = Vec::new();
        for (p, row) in st.ledger.iter().enumerate() {
            for (c, l) in row.iter().enumerate() {
                if l.samples == 0 {
                    continue;
                }
                let mean_bytes = (l.bytes / l.samples) as usize;
                let mean_wall = l.wall_ns / l.samples as f64;
                // Engine residuals are priced at the SameNode roofline —
                // the locality where the cutover decision lives; the
                // synthetic calibration sweep feeds SameNode observations
                // so its residuals are exact.
                let predicted = match p {
                    PATH_ENGINE_IMM => self.predict_engine_ns(Locality::SameNode, mean_bytes, true),
                    PATH_ENGINE_STD => {
                        self.predict_engine_ns(Locality::SameNode, mean_bytes, false)
                    }
                    _ => self.predict_rail_ns(mean_bytes),
                };
                classes.push(ClassRow {
                    path: PATH_NAMES[p],
                    class: service_size_label(c),
                    samples: l.samples,
                    mean_wall_ns: mean_wall,
                    predicted_ns: predicted,
                    residual: (mean_wall - predicted).abs() / mean_wall.abs().max(1e-12),
                });
            }
        }
        CalibrationSnapshot {
            enabled: self.cfg.enable,
            model_version: self.cost.model.version(),
            params,
            classes,
        }
    }
}

/// One learned-quantity row of the calibration snapshot.
#[derive(Clone, Debug)]
pub struct ParamRow {
    pub name: &'static str,
    pub configured: f64,
    pub learned: f64,
    /// Implied-value observations this quantity has absorbed.
    pub samples: u64,
}

/// One (path, size-class) residual row of the calibration snapshot.
#[derive(Clone, Debug)]
pub struct ClassRow {
    pub path: &'static str,
    pub class: &'static str,
    pub samples: u64,
    pub mean_wall_ns: f64,
    pub predicted_ns: f64,
    /// |observed − predicted| / observed at the current learned params.
    pub residual: f64,
}

/// Snapshot of the calibration state: learned vs configured params and
/// per-class residuals (report + `rishmem metrics --json`).
#[derive(Clone, Debug)]
pub struct CalibrationSnapshot {
    pub enabled: bool,
    pub model_version: u64,
    pub params: Vec<ParamRow>,
    pub classes: Vec<ClassRow>,
}

impl CalibrationSnapshot {
    /// Mean residual over the populated (path, class) rows — the single
    /// convergence number `fig_calib` tracks per round.
    pub fn mean_residual(&self) -> f64 {
        if self.classes.is_empty() {
            return 0.0;
        }
        self.classes.iter().map(|c| c.residual).sum::<f64>() / self.classes.len() as f64
    }

    /// Human-readable report (`rishmem figure calibration` body).
    pub fn report(&self) -> String {
        let mut out = format!(
            "calibration: learned vs configured params (enabled={}, model-version={})\n\
             param                      configured    learned       samples\n",
            self.enabled, self.model_version
        );
        for p in &self.params {
            out.push_str(&format!(
                "{:<26} {:<13.6} {:<13.6} {}\n",
                p.name, p.configured, p.learned, p.samples
            ));
        }
        out.push_str(
            "\nper-class residual |wall - model| / wall at the learned params\n\
             path         size       samples   mean-wall-ns   predicted-ns   residual\n",
        );
        for c in &self.classes {
            out.push_str(&format!(
                "{:<12} {:<10} {:<9} {:<14.0} {:<14.0} {:.4}\n",
                c.path, c.class, c.samples, c.mean_wall_ns, c.predicted_ns, c.residual
            ));
        }
        out.push_str(&format!("mean residual: {:.4}\n", self.mean_residual()));
        out
    }

    /// JSON value for `rishmem metrics --json` (merged into the metrics
    /// snapshot object under the "calibration" key).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let params = self
            .params
            .iter()
            .map(|p| {
                let mut o: BTreeMap<String, Json> = BTreeMap::new();
                o.insert("name".into(), Json::Str(p.name.into()));
                o.insert("configured".into(), Json::Num(p.configured));
                o.insert("learned".into(), Json::Num(p.learned));
                o.insert("samples".into(), Json::Num(p.samples as f64));
                Json::Obj(o)
            })
            .collect();
        let classes = self
            .classes
            .iter()
            .map(|c| {
                let mut o: BTreeMap<String, Json> = BTreeMap::new();
                o.insert("path".into(), Json::Str(c.path.into()));
                o.insert("class".into(), Json::Str(c.class.into()));
                o.insert("samples".into(), Json::Num(c.samples as f64));
                o.insert("mean_wall_ns".into(), Json::Num(c.mean_wall_ns));
                o.insert("predicted_ns".into(), Json::Num(c.predicted_ns));
                o.insert("residual".into(), Json::Num(c.residual));
                Json::Obj(o)
            })
            .collect();
        let mut top: BTreeMap<String, Json> = BTreeMap::new();
        top.insert("enabled".into(), Json::Bool(self.enabled));
        top.insert("model_version".into(), Json::Num(self.model_version as f64));
        top.insert("mean_residual".into(), Json::Num(self.mean_residual()));
        top.insert("params".into(), Json::Arr(params));
        top.insert("classes".into(), Json::Arr(classes));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fault::FaultConfig;
    use crate::sim::{CostParams, Topology};

    fn enabled_cfg() -> CalibConfig {
        CalibConfig {
            enable: true,
            ema_alpha: 0.25,
            min_samples: 16,
            clamp_frac: 4.0,
        }
    }

    fn calibrator(cfg: CalibConfig) -> Calibrator {
        let cost = CostModel::new(Topology::default(), CostParams::default());
        Calibrator::new(cost, cfg)
    }

    /// Ground-truth engine dispatch time under planted params.
    fn truth_engine_ns(
        cal: &Calibrator,
        bytes: usize,
        immediate: bool,
        frac: f64,
        s_imm: f64,
        s_std: f64,
    ) -> f64 {
        let roofline = cal
            .cost
            .params
            .ce
            .path_bw_gbs(&cal.cost.params.xe, Locality::SameNode);
        (if immediate { s_imm } else { s_std }) + bytes as f64 / (roofline * frac)
    }

    fn truth_rail_ns(cal: &Calibrator, bytes: usize, frac: f64, startup: f64) -> f64 {
        startup + bytes as f64 / (cal.cost.params.nic.bw_gbs * frac)
    }

    /// Feed `rounds` of a consistent truth stream across the startup and
    /// bandwidth classes.
    fn feed_truth(cal: &Calibrator, rounds: usize, frac: f64, s_imm: f64, s_std: f64) {
        for _ in 0..rounds {
            for &bytes in &[2 << 10, 16 << 10, 512 << 10, 1 << 20, 4 << 20] {
                for &imm in &[true, false] {
                    let t = truth_engine_ns(cal, bytes, imm, frac, s_imm, s_std);
                    cal.observe_engine(Locality::SameNode, bytes, imm, t);
                }
            }
        }
    }

    #[test]
    fn converges_to_planted_engine_ground_truth() {
        // Acceptance bar: learned single_engine_frac lands within 10% of
        // a planted ground truth fed through a synthetic observation
        // stream (seed 0.25, truth 0.5 — a 2× error the clamp permits).
        let cal = calibrator(enabled_cfg());
        let (frac_t, s_imm_t, s_std_t) = (0.5, 4_000.0, 7_000.0);
        feed_truth(&cal, 60, frac_t, s_imm_t, s_std_t);
        let live = cal.cost.model.get();
        assert!(
            (live.single_engine_frac - frac_t).abs() / frac_t < 0.10,
            "learned frac {} not within 10% of {frac_t}",
            live.single_engine_frac
        );
        assert!(
            (live.startup_immediate_ns - s_imm_t).abs() / s_imm_t < 0.10,
            "learned imm startup {} not within 10% of {s_imm_t}",
            live.startup_immediate_ns
        );
        assert!(
            (live.startup_standard_ns - s_std_t).abs() / s_std_t < 0.10,
            "learned std startup {} not within 10% of {s_std_t}",
            live.startup_standard_ns
        );
        assert!(cal.cost.model.version() > 0, "convergence must bump the version");
        // The residuals at the learned params are small.
        assert!(cal.snapshot().mean_residual() < 0.05, "{}", cal.snapshot().report());
    }

    #[test]
    fn converges_to_planted_rail_ground_truth() {
        let cal = calibrator(enabled_cfg());
        let (frac_t, startup_t) = (0.5, 900.0);
        for _ in 0..60 {
            for &bytes in &[2 << 10, 16 << 10, 512 << 10, 2 << 20, 8 << 20] {
                let t = truth_rail_ns(&cal, bytes, frac_t, startup_t);
                cal.observe_rail(0, 0, bytes, t);
            }
        }
        let live = cal.cost.model.get();
        assert!(
            (live.rail_bw_frac - frac_t).abs() / frac_t < 0.10,
            "learned rail frac {} not within 10% of {frac_t}",
            live.rail_bw_frac
        );
        assert!(
            (live.rail_startup_ns - startup_t).abs() / startup_t < 0.10,
            "learned rail startup {} not within 10% of {startup_t}",
            live.rail_startup_ns
        );
    }

    #[test]
    fn poisoned_initial_guess_recovers() {
        // Mirror of the PR-3 epsilon-exploration property test: a stream
        // that starts with wildly wrong observations (implying a frac
        // near the clamp floor) recovers once honest observations flow.
        let cal = calibrator(enabled_cfg());
        let truth = 0.5;
        // Poison: large transfers reported 10× slower than even a
        // floor-fraction engine would run.
        for _ in 0..40 {
            let honest = truth_engine_ns(&cal, 4 << 20, true, truth, 4_000.0, 7_000.0);
            cal.observe_engine(Locality::SameNode, 4 << 20, true, honest * 10.0);
        }
        let poisoned = cal.cost.model.get().single_engine_frac;
        assert!(poisoned < 0.1, "poison did not take: {poisoned}");
        // Recovery: honest stream.
        feed_truth(&cal, 80, truth, 4_000.0, 7_000.0);
        let recovered = cal.cost.model.get().single_engine_frac;
        assert!(
            (recovered - truth).abs() / truth < 0.10,
            "poisoned guess never recovered: {recovered} vs {truth}"
        );
    }

    #[test]
    fn disabled_calibrator_never_touches_the_model() {
        let cal = calibrator(CalibConfig::default());
        assert!(!cal.enabled());
        let before = cal.cost.model.get();
        feed_truth(&cal, 50, 0.9, 100.0, 100.0);
        cal.observe_rail(0, 0, 8 << 20, 1.0);
        cal.refine_cl_boundary();
        assert_eq!(cal.cost.model.version(), 0);
        let after = cal.cost.model.get();
        assert_eq!(after.single_engine_frac.to_bits(), before.single_engine_frac.to_bits());
        assert_eq!(after.rail_bw_frac.to_bits(), before.rail_bw_frac.to_bits());
        let snap = cal.snapshot();
        assert!(!snap.enabled);
        assert!(snap.classes.is_empty(), "disabled ledgers must stay empty");
    }

    #[test]
    fn clamp_bounds_learned_values_around_the_seed() {
        let cal = calibrator(enabled_cfg());
        // Absurd truth: startups 100× the seed. The learner clamps at
        // seed × clamp_frac.
        for _ in 0..60 {
            cal.observe_engine(Locality::SameNode, 2 << 10, true, 320_000.0);
        }
        let live = cal.cost.model.get();
        let seed = cal.cost.model.seed();
        assert!(
            live.startup_immediate_ns <= seed.startup_immediate_ns * 4.0 + 1e-9,
            "clamp violated: {} vs seed {}",
            live.startup_immediate_ns,
            seed.startup_immediate_ns
        );
        // Fractions additionally cap at 1.0 no matter the stream.
        for _ in 0..60 {
            // Implausibly fast large transfers (implying frac > 1 before
            // the per-observation clamp).
            cal.observe_engine(Locality::SameNode, 8 << 20, true, 1.0);
        }
        assert!(cal.cost.model.get().single_engine_frac <= 1.0);
    }

    #[test]
    fn cl_boundary_nudges_toward_observed_crossover() {
        let cal = calibrator(enabled_cfg());
        cal.cost.model.seed_cl_boundary(64 << 10);
        // Synthetic per-byte flavor costs: immediate is cheaper up through
        // the ≤256KiB class, standard wins from the ≤1MiB class up — the
        // observed crossover sits at the 256KiB boundary.
        for _ in 0..20 {
            for (c, &bytes) in [2 << 10, 16 << 10, 128 << 10, 512 << 10, 2 << 20, 8 << 20]
                .iter()
                .enumerate()
            {
                let (imm_pb, std_pb) = if c < 3 { (1.0, 2.0) } else { (2.0, 1.0) };
                cal.observe_cl_flavor(bytes, true, imm_pb);
                cal.observe_cl_flavor(bytes, false, std_pb);
            }
        }
        for _ in 0..40 {
            cal.refine_cl_boundary();
        }
        let learned = cal.cost.model.get().cl_immediate_max_bytes;
        assert_ne!(learned, 64 << 10, "boundary never moved");
        assert!(
            learned > 64 << 10 && learned <= 256 << 10,
            "boundary {learned} did not move toward the 256KiB crossover"
        );
        // The seed clamp still anchors it.
        assert!(learned <= (64 << 10) * 4);
    }

    #[test]
    fn cl_boundary_learns_from_disjoint_flavor_evidence() {
        // The live shape: the boundary itself decides each entry's
        // flavor, so immediate evidence lives strictly below the boundary
        // class and standard evidence strictly above — the frontier
        // comparison must still move the boundary.
        let cal = calibrator(enabled_cfg());
        cal.cost.model.seed_cl_boundary(64 << 10);
        // Immediate cheap in classes 0–1, standard expensive in 2+:
        // immediate wins its frontier → the window grows.
        for _ in 0..20 {
            for &bytes in &[2 << 10, 16 << 10] {
                cal.observe_cl_flavor(bytes, true, 1.0);
            }
            for &bytes in &[128 << 10, 512 << 10] {
                cal.observe_cl_flavor(bytes, false, 3.0);
            }
        }
        for _ in 0..64 {
            cal.refine_cl_boundary();
        }
        let grown = cal.cost.model.get().cl_immediate_max_bytes;
        assert!(grown > 64 << 10, "boundary did not grow: {grown}");
        // Flip the evidence (standard now cheap at the frontier): the
        // window shrinks back down, still clamped around the seed.
        let cal = calibrator(enabled_cfg());
        cal.cost.model.seed_cl_boundary(64 << 10);
        for _ in 0..20 {
            for &bytes in &[2 << 10, 16 << 10] {
                cal.observe_cl_flavor(bytes, true, 3.0);
            }
            for &bytes in &[128 << 10, 512 << 10] {
                cal.observe_cl_flavor(bytes, false, 1.0);
            }
        }
        for _ in 0..64 {
            cal.refine_cl_boundary();
        }
        let shrunk = cal.cost.model.get().cl_immediate_max_bytes;
        assert!(shrunk < 64 << 10, "boundary did not shrink: {shrunk}");
        assert!(shrunk >= (64 << 10) / 4, "clamp floor violated: {shrunk}");
    }

    #[test]
    fn collapsed_rail_is_quarantined_and_probed_back() {
        let cal = calibrator(enabled_cfg());
        let plane = FaultPlane::new(
            Arc::clone(&cal.cost),
            FaultConfig {
                enable: true,
                detect_min_samples: 8,
                probe_after: 24,
                ..FaultConfig::default()
            },
        );
        cal.set_fault_plane(Arc::clone(&plane));
        let healthy = truth_rail_ns(&cal, 4 << 20, 0.5, 900.0);
        let kill = FaultAction::KillRail { node: 0, rail: 2 };
        let revive = FaultAction::ReviveRail { node: 0, rail: 2 };
        let mut actions = Vec::new();
        for _ in 0..40 {
            for r in [0usize, 1, 3] {
                actions.extend(cal.observe_rail(0, r, 4 << 20, healthy));
            }
            if !actions.contains(&kill) {
                // Rail 2 runs 10× slower than its peers: its implied
                // fraction collapses far below detect_frac × peer mean.
                actions.extend(cal.observe_rail(0, 2, 4 << 20, healthy * 10.0));
            }
        }
        let ki = actions.iter().position(|a| *a == kill).expect("rail 2 never quarantined");
        let ri = actions
            .iter()
            .position(|a| *a == revive)
            .expect("quarantined rail never probed back");
        assert!(ki < ri, "probe before quarantine: {actions:?}");
        assert_eq!(actions.len(), 2, "spurious transitions: {actions:?}");
        // The probe revived it and no fresh evidence re-killed it.
        assert!(cal.cost.rail_is_live(0, 2));
        assert_eq!(cal.cost.health_generation(), 2, "kill + revive");
        assert!(!cal.cost.degraded());
    }

    #[test]
    fn detector_is_inert_without_an_enabled_plane() {
        // No plane attached: collapsed evidence never kills anything.
        let run = |cal: &Calibrator| {
            let healthy = truth_rail_ns(cal, 4 << 20, 0.5, 900.0);
            for _ in 0..40 {
                for r in 0..3 {
                    assert!(cal.observe_rail(0, r, 4 << 20, healthy).is_none());
                }
                assert!(cal.observe_rail(0, 3, 4 << 20, healthy * 10.0).is_none());
            }
            assert!(cal.cost.rail_is_live(0, 3));
            assert_eq!(cal.cost.health_generation(), 0);
        };
        let cal = calibrator(enabled_cfg());
        run(&cal);
        // A *disabled* plane attached (the default config): still inert.
        let cal = calibrator(enabled_cfg());
        cal.set_fault_plane(FaultPlane::new(Arc::clone(&cal.cost), FaultConfig::default()));
        run(&cal);
    }

    #[test]
    fn snapshot_reports_and_serializes() {
        let cal = calibrator(enabled_cfg());
        feed_truth(&cal, 20, 0.5, 4_000.0, 7_000.0);
        cal.observe_rail(0, 0, 2 << 20, truth_rail_ns(&cal, 2 << 20, 0.5, 900.0));
        let snap = cal.snapshot();
        assert!(snap.enabled);
        assert_eq!(snap.params.len(), QUANTITIES);
        assert!(!snap.classes.is_empty());
        let report = snap.report();
        assert!(report.contains("ce.single_engine_frac"), "{report}");
        assert!(report.contains("engine-imm"), "{report}");
        assert!(report.contains("mean residual"), "{report}");
        // JSON round-trips through the hand-rolled parser.
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(j.get("enabled"), Some(&Json::Bool(true)));
        assert!(j.get("params").unwrap().as_arr().unwrap().len() == QUANTITIES);
        assert!(j.get("mean_residual").unwrap().as_f64().is_some());
    }
}
