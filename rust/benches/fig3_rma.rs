//! Bench E1/E2: regenerates paper Fig 3 (put/get bandwidth, three
//! hardware paths, vs ze_peer) and asserts the paper-shape invariants.
//! `cargo bench --bench fig3_rma`

use rishmem::bench::figures::{fig3a, fig3b};

fn main() {
    for fig in [fig3a(), fig3b()] {
        println!("{}", fig.render_ascii());

        // Shape invariants from the paper:
        // 1. ishmem beats ze_peer for small messages (≤2KB) on every path.
        for path in ["same-tile", "cross-tile", "cross-GPU"] {
            let ish = fig
                .series
                .iter()
                .find(|s| s.name == format!("ishmem {path}"))
                .unwrap();
            let zep = fig
                .series
                .iter()
                .find(|s| s.name == format!("ze_peer {path}"))
                .unwrap();
            for &(x, y) in ish.points.iter().filter(|(x, _)| *x <= 2048.0) {
                let z = zep.y_at(x).unwrap();
                assert!(y > z, "{}: ishmem {path} {y} !> ze_peer {z} at {x}B", fig.id);
            }
            // 2. converge within 15% at 16MB.
            let (xl, yl) = *ish.points.last().unwrap();
            let zl = zep.y_at(xl).unwrap();
            assert!(
                (yl - zl).abs() / zl < 0.15,
                "{}: no convergence at {xl}B: {yl} vs {zl}",
                fig.id
            );
        }
        // 3. locality ordering at large sizes.
        let big = 1_048_576.0;
        let y = |n: &str| fig.series.iter().find(|s| s.name == n).unwrap().y_at(big).unwrap();
        assert!(y("ishmem same-tile") > y("ishmem cross-tile"));
        assert!(y("ishmem cross-tile") > y("ishmem cross-GPU"));
        println!("[{}] paper-shape invariants hold\n", fig.id);
    }
}
