//! Bench: wall-clock cost of the L3 hot paths (the library's own
//! overhead, independent of the modeled hardware time) — put issue path,
//! AMO path, sync, and the proxy round trip — plus the planner's
//! plans/sec microbench (cached vs uncached, single- and multi-threaded).
//! This is the profile target for the §Perf optimization pass.
//! `cargo bench --bench hot_path` (`RISHMEM_SMOKE=1` shrinks the sweeps)

use std::sync::Arc;

use rishmem::bench::measure_wall;
use rishmem::coordinator::metrics::Metrics;
use rishmem::ishmem::CutoverConfig;
use rishmem::sim::{CostModel, CostParams, Topology};
use rishmem::xfer::{OpKind, PlanCacheConfig, XferEngine};
use rishmem::{Ishmem, IshmemConfig, Locality, ReduceOp, TeamId};

/// The repeated shape set the planner sweeps: all three routes, sizes
/// straddling the cutover and striping regimes.
fn plan_shapes() -> Vec<(bool, Locality, usize, usize)> {
    let mut v = Vec::new();
    for &bytes in &[64usize, 4096, 64 << 10, 1 << 20, 8 << 20] {
        for &loc in &[Locality::SameTile, Locality::SameNode] {
            v.push((true, loc, bytes, 1));
        }
        v.push((false, Locality::Remote, bytes, 1));
    }
    v
}

fn plan_engine(cache_on: bool) -> XferEngine {
    let cost = CostModel::new(Topology::default(), CostParams::default());
    let mut e = XferEngine::new(cost, CutoverConfig::tuned(), true, Metrics::new());
    e.set_plan_cache(PlanCacheConfig { enable: cache_on, capacity: 4096 });
    e
}

/// Plans/sec over `iters` plans cycling the shape set; the modeled-ns
/// sum is folded into a sink so the planning work cannot be elided.
fn plans_per_sec(e: &XferEngine, shapes: &[(bool, Locality, usize, usize)], iters: usize) -> f64 {
    let t0 = std::time::Instant::now();
    let mut sink = 0.0f64;
    for i in 0..iters {
        let (reach, loc, bytes, items) = shapes[i % shapes.len()];
        sink += e.plan_p2p(OpKind::Put, reach, loc, bytes, items).modeled_ns;
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    iters as f64 / dt.max(1e-9)
}

fn plans_per_sec_mt(
    e: &Arc<XferEngine>,
    shapes: &[(bool, Locality, usize, usize)],
    iters: usize,
    threads: usize,
) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let e = Arc::clone(e);
            let shapes = shapes.to_vec();
            s.spawn(move || {
                let mut sink = 0.0f64;
                for i in 0..iters / threads {
                    let (reach, loc, bytes, items) = shapes[i % shapes.len()];
                    sink += e.plan_p2p(OpKind::Put, reach, loc, bytes, items).modeled_ns;
                }
                std::hint::black_box(sink);
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    (iters - iters % threads) as f64 / dt.max(1e-9)
}

fn bench_planner(smoke: bool) {
    let shapes = plan_shapes();
    let iters = if smoke { 20_000 } else { 200_000 };
    let cached = plan_engine(true);
    let uncached = plan_engine(false);

    // Warm the cache, and hold the zero-drift property while at it:
    // every cached plan must be bit-identical to the cache-off plan.
    for &(reach, loc, bytes, items) in &shapes {
        let c = cached.plan_p2p(OpKind::Put, reach, loc, bytes, items);
        let u = uncached.plan_p2p(OpKind::Put, reach, loc, bytes, items);
        assert_eq!(c, u, "cold cached plan drifted from uncached");
    }
    for &(reach, loc, bytes, items) in &shapes {
        let c = cached.plan_p2p(OpKind::Put, reach, loc, bytes, items); // warm hit
        let u = uncached.plan_p2p(OpKind::Put, reach, loc, bytes, items);
        assert_eq!(c, u, "warm cached plan drifted from uncached");
    }

    let warm = plans_per_sec(&cached, &shapes, iters);
    let cold = plans_per_sec(&uncached, &shapes, iters);
    let ratio = warm / cold;
    println!("\n== planner plans/sec (single thread) ==");
    println!("  cache-warm : {warm:12.0} plans/s");
    println!("  uncached   : {cold:12.0} plans/s   (snapshot-refactor baseline)");
    println!("  speedup    : {ratio:12.2}x");
    let floor = if smoke { 2.0 } else { 5.0 };
    assert!(
        ratio >= floor,
        "cache-warm planning must be at least {floor}x uncached, got {ratio:.2}x"
    );

    let threads = 4;
    let cached = Arc::new(plan_engine(true));
    for &(reach, loc, bytes, items) in &shapes {
        cached.plan_p2p(OpKind::Put, reach, loc, bytes, items); // pre-warm
    }
    let uncached = Arc::new(plan_engine(false));
    let warm_mt = plans_per_sec_mt(&cached, &shapes, iters, threads);
    let cold_mt = plans_per_sec_mt(&uncached, &shapes, iters, threads);
    let ratio_mt = warm_mt / cold_mt;
    println!("== planner plans/sec ({threads} threads) ==");
    println!("  cache-warm : {warm_mt:12.0} plans/s");
    println!("  uncached   : {cold_mt:12.0} plans/s");
    println!("  speedup    : {ratio_mt:12.2}x");
    let floor_mt = if smoke { 1.2 } else { 2.0 };
    assert!(
        ratio_mt >= floor_mt,
        "concurrent cache-warm planning must be at least {floor_mt}x uncached, got {ratio_mt:.2}x"
    );
}

fn main() {
    let smoke = std::env::var("RISHMEM_SMOKE").is_ok();
    let cfg = IshmemConfig {
        cutover: CutoverConfig::never(),
        ..IshmemConfig::with_npes(2)
    };
    let ish = Ishmem::new(cfg).expect("machine");
    let results = ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        let word = ctx.calloc::<u64>(1);
        let red_d = ctx.calloc::<f32>(256);
        let red_s = ctx.calloc::<f32>(256);
        ctx.barrier_all();
        if ctx.pe() != 0 {
            // PE 1 participates in the collective phases at the end.
            ctx.barrier_all();
            for _ in 0..3 {
                ctx.reduce(red_d, red_s, 256, ReduceOp::Sum, TeamId::WORLD);
            }
            for _ in 0..1000 {
                ctx.sync_all();
            }
            return Vec::new();
        }

        let payload8 = [0u8; 8];
        let payload4k = vec![0u8; 4096];
        let mut out = Vec::new();

        let m = measure_wall(|| ctx.put(buf, &payload8, 1));
        out.push(("put 8B (load/store wall)".to_string(), m.best_ns));

        let m = measure_wall(|| ctx.put(buf, &payload4k, 1));
        out.push(("put 4KB (load/store wall)".to_string(), m.best_ns));

        let m = measure_wall(|| ctx.p(word, 1u64, 1));
        out.push(("scalar p (wall)".to_string(), m.best_ns));

        let m = measure_wall(|| ctx.atomic_add(word, 1u64, 1));
        out.push(("atomic_add (wall)".to_string(), m.best_ns));

        let m = measure_wall(|| {
            ctx.atomic_fetch_add(word, 1u64, 1);
        });
        out.push(("atomic_fetch_add (wall)".to_string(), m.best_ns));

        ctx.barrier_all();
        // Collectives (fixed plan with PE 1 above).
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            ctx.reduce(red_d, red_s, 256, ReduceOp::Sum, TeamId::WORLD);
        }
        out.push((
            "reduce 256 f32 (wall, 2 PEs)".to_string(),
            t0.elapsed().as_nanos() as f64 / 3.0,
        ));
        let t0 = std::time::Instant::now();
        for _ in 0..1000 {
            ctx.sync_all();
        }
        out.push((
            "sync_all (wall, 2 PEs)".to_string(),
            t0.elapsed().as_nanos() as f64 / 1000.0,
        ));
        out
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    println!("== L3 hot-path wall-clock (library overhead, 1-core box) ==");
    for (name, ns) in results.into_iter().flatten() {
        println!("  {name:34} {ns:10.0} ns");
    }
    println!("\nmetrics after run:\n{}", snap.report());

    bench_planner(smoke);
}
