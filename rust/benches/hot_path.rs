//! Bench: wall-clock cost of the L3 hot paths (the library's own
//! overhead, independent of the modeled hardware time) — put issue path,
//! AMO path, sync, and the proxy round trip. This is the profile target
//! for the §Perf optimization pass.
//! `cargo bench --bench hot_path`

use rishmem::bench::measure_wall;
use rishmem::ishmem::CutoverConfig;
use rishmem::{Ishmem, IshmemConfig, ReduceOp, TeamId};

fn main() {
    let cfg = IshmemConfig {
        cutover: CutoverConfig::never(),
        ..IshmemConfig::with_npes(2)
    };
    let ish = Ishmem::new(cfg).expect("machine");
    let results = ish.launch(|ctx| {
        let buf = ctx.calloc::<u8>(1 << 20);
        let word = ctx.calloc::<u64>(1);
        let red_d = ctx.calloc::<f32>(256);
        let red_s = ctx.calloc::<f32>(256);
        ctx.barrier_all();
        if ctx.pe() != 0 {
            // PE 1 participates in the collective phases at the end.
            ctx.barrier_all();
            for _ in 0..3 {
                ctx.reduce(red_d, red_s, 256, ReduceOp::Sum, TeamId::WORLD);
            }
            for _ in 0..1000 {
                ctx.sync_all();
            }
            return Vec::new();
        }

        let payload8 = [0u8; 8];
        let payload4k = vec![0u8; 4096];
        let mut out = Vec::new();

        let m = measure_wall(|| ctx.put(buf, &payload8, 1));
        out.push(("put 8B (load/store wall)".to_string(), m.best_ns));

        let m = measure_wall(|| ctx.put(buf, &payload4k, 1));
        out.push(("put 4KB (load/store wall)".to_string(), m.best_ns));

        let m = measure_wall(|| ctx.p(word, 1u64, 1));
        out.push(("scalar p (wall)".to_string(), m.best_ns));

        let m = measure_wall(|| ctx.atomic_add(word, 1u64, 1));
        out.push(("atomic_add (wall)".to_string(), m.best_ns));

        let m = measure_wall(|| {
            ctx.atomic_fetch_add(word, 1u64, 1);
        });
        out.push(("atomic_fetch_add (wall)".to_string(), m.best_ns));

        ctx.barrier_all();
        // Collectives (fixed plan with PE 1 above).
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            ctx.reduce(red_d, red_s, 256, ReduceOp::Sum, TeamId::WORLD);
        }
        out.push((
            "reduce 256 f32 (wall, 2 PEs)".to_string(),
            t0.elapsed().as_nanos() as f64 / 3.0,
        ));
        let t0 = std::time::Instant::now();
        for _ in 0..1000 {
            ctx.sync_all();
        }
        out.push((
            "sync_all (wall, 2 PEs)".to_string(),
            t0.elapsed().as_nanos() as f64 / 1000.0,
        ));
        out
    });
    let snap = ish.metrics.snapshot();
    ish.shutdown();

    println!("== L3 hot-path wall-clock (library overhead, 1-core box) ==");
    for (name, ns) in results.into_iter().flatten() {
        println!("  {name:34} {ns:10.0} ns");
    }
    println!("\nmetrics after run:\n{}", snap.report());
}
