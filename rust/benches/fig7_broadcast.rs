//! Bench E8/E9: paper Fig 7 — tuned fcollect at 12 PEs (a) and broadcast
//! scaling with PE count at 128 work-items (b).
//! `cargo bench --bench fig7_broadcast`

use rishmem::bench::figures::{fig7a, fig7b};

fn main() {
    let a = fig7a();
    println!("{}", a.render_ascii());
    // Tuned fcollect must never fall (much) below the host engine — the
    // adaptive policy switches to it when stores lose (paper Fig 7a).
    let host = a.series.iter().find(|s| s.name == "host copy-engine").unwrap();
    for s in a.series.iter().filter(|s| s.name.contains("work-items")) {
        for &(x, y) in &s.points {
            let h = host.y_at(x).unwrap();
            assert!(
                y >= h * 0.90,
                "fig7a: tuned {} {y} fell below host engine {h} at {x} elems",
                s.name
            );
        }
    }
    println!("[fig7a] tuned cutover keeps fcollect at/above the host-engine line\n");

    let b = fig7b();
    println!("{}", b.render_ascii());
    // Paper Fig 7(b): "The performance for 2 PE broadcast stands out as
    // the two PEs … are using two tiles within the same GPU".
    let big = *b.series[0].points.last().map(|(x, _)| x).unwrap();
    let y2 = b.series.iter().find(|s| s.name == "2 PEs").unwrap().y_at(big).unwrap();
    for s in b.series.iter().filter(|s| s.name != "2 PEs") {
        let y = s.y_at(big).unwrap();
        assert!(
            y2 > y,
            "fig7b: 2-PE broadcast should stand out: {y2} !> {y} ({})",
            s.name
        );
    }
    // Uniform scaling beyond 2 PEs: 4..12 PEs within a tight band at the
    // largest size (per-PE bandwidth limited by the same Xe-Links).
    let ys: Vec<f64> = b
        .series
        .iter()
        .filter(|s| s.name != "2 PEs")
        .map(|s| s.y_at(big).unwrap())
        .collect();
    let (min, max) = ys
        .iter()
        .fold((f64::INFINITY, 0.0f64), |(lo, hi), &y| (lo.min(y), hi.max(y)));
    assert!(
        max / min < 3.0,
        "fig7b: 4–12 PE broadcast spread too wide: {ys:?}"
    );
    println!("[fig7b] 2-PE standout + uniform scaling beyond, as in the paper");
}
