//! Bench: multi-engine striped transfers (ISSUE 3). Large same-node puts
//! pipeline chunked slabs across 4+ copy engines; the acceptance bar is
//! ≥2× modeled throughput vs the same machine pinned to a single engine,
//! for every ≥1 MiB point.
//! `cargo bench --bench fig_stripe` (`RISHMEM_SMOKE=1` shrinks the sweep).

use rishmem::bench::figures::fig_stripe;

fn main() {
    let fig = fig_stripe();
    println!("{}", fig.render_ascii());

    let single = fig
        .series
        .iter()
        .find(|s| s.name == "single-engine")
        .expect("single-engine series");
    let striped = fig
        .series
        .iter()
        .find(|s| s.name == "striped")
        .expect("striped series");

    for &(x, y) in &striped.points {
        let base = single.y_at(x).expect("matching single-engine point");
        println!(
            "[fig_stripe] {x:>10.0} B: striped {y:6.2} GB/s vs single-engine {base:6.2} GB/s \
             ({:.1}x)",
            y / base
        );
        if x >= (1 << 20) as f64 {
            assert!(
                y >= base * 2.0,
                "striping under 2x at {x}B: {y} vs {base} GB/s"
            );
        }
    }
    // The striped pipeline must approach the engine-path roofline (the
    // 25 GB/s Xe-Link), not just beat a slow baseline.
    let (_, best) = *striped.points.last().unwrap();
    assert!(
        best > 15.0,
        "striped large-put bandwidth {best} GB/s nowhere near the link roofline"
    );
    println!("[fig_stripe] striped chunk pipeline sustains >=2x single-engine throughput");
}
