//! Bench: fault injection and degraded-mode re-striping (ISSUE 8). A
//! 4-rail machine loses NIC rail (0, 1); new plans must re-stripe onto
//! the 3 survivors so remote-put throughput converges to the model of a
//! machine *configured* with 3 rails — and reviving the rail must
//! restore the healthy series bit for bit. Acceptance bars:
//! (a) degraded throughput within 2% of the (N−1)-rail model at every
//! point, (b) strictly below healthy at the largest (width-limited)
//! size, (c) recovery exactly equals healthy, (d) the cost model's
//! stripe shapes and drain estimates under a kill are bit-for-bit the
//! (N−1)-rail config's.
//! `cargo bench --bench fig_fault` (`RISHMEM_SMOKE=1` shrinks the sweep).

use rishmem::bench::figures::fig_fault;
use rishmem::sim::cost::{CostModel, CostParams};
use rishmem::sim::Topology;

fn main() {
    let fig = fig_fault();
    println!("{}", fig.render_ascii());

    let series = |name: &str| {
        fig.series
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing series {name:?}"))
    };
    let healthy = series("healthy-4rail");
    let degraded = series("degraded-3live");
    let model = series("model-3rail");
    let recovered = series("recovered");

    let largest = healthy.points.last().expect("non-empty sweep").0;
    for &(x, y) in &degraded.points {
        let m = model.y_at(x).expect("matching model-3rail point");
        let h = healthy.y_at(x).expect("matching healthy point");
        println!(
            "[fig_fault] {x:>10.0} B: degraded {y:6.2} GB/s vs (N-1)-model {m:6.2} GB/s \
             (healthy {h:6.2})"
        );
        let rel = (y - m).abs() / m;
        assert!(
            rel <= 0.02,
            "degraded throughput did not converge to the (N-1)-rail model at {x}B: \
             {y} vs {m} GB/s ({:.1}% off)",
            rel * 100.0
        );
        if x == largest {
            assert!(
                h > y,
                "killing a rail did not cost throughput at the width-limited size {x}B: \
                 healthy {h} !> degraded {y}"
            );
        }
    }
    for &(x, y) in &recovered.points {
        let h = healthy.y_at(x).expect("matching healthy point");
        assert!(
            y == h,
            "revival did not restore healthy throughput bit-for-bit at {x}B: {y} != {h}"
        );
    }

    // Estimate-level bars: a 4-rail model with one rail dead prices
    // stripes and backlog drains bit-for-bit like a 3-rail config.
    let mut p = CostParams::default();
    p.nic.rails = 4;
    let four = CostModel::new(Topology::new(2, 2, 2), p.clone());
    assert!(four.kill_rail(0, 1));
    p.nic.rails = 3;
    let three = CostModel::new(Topology::new(2, 2, 2), p);
    for shift in [16usize, 20, 22, 23] {
        let bytes = 1 << shift;
        assert_eq!(
            four.rail_stripe_for(bytes, usize::MAX),
            three.rail_stripe_for(bytes, usize::MAX),
            "stripe shape diverges from the (N-1)-rail config at {bytes}B"
        );
        let (a, b) = (four.rail_drain_ns(bytes as u64), three.rail_drain_ns(bytes as u64));
        assert!(a == b, "drain estimate diverges at {bytes}B: {a} != {b}");
    }
    assert!(four.revive_rail(0, 1));
    assert!(!four.degraded(), "revival left the model degraded");

    println!(
        "[fig_fault] rail kill converges to the (N-1)-rail model; revival restores \
         healthy throughput bit-for-bit"
    );
}
