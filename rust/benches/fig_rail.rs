//! Bench: multi-rail NIC striping on the remote path (ISSUE 4). Large
//! cross-node puts slice into slab-staged chunks carrying rail hints and
//! inject across 4 NIC rails; the acceptance bars are (a) ≥2× modeled
//! throughput vs the same machine pinned to a single rail for every
//! ≥1 MiB point, and (b) ramped first chunks (`stripe.ramp_factor`)
//! strictly reduce modeled time-to-first-byte at equal total bytes, on
//! both the rail and the engine stripe.
//! `cargo bench --bench fig_rail` (`RISHMEM_SMOKE=1` shrinks the sweep).

use rishmem::bench::figures::fig_rail;
use rishmem::sim::cost::{CostModel, CostParams};
use rishmem::sim::{Locality, Topology};

fn main() {
    let fig = fig_rail();
    println!("{}", fig.render_ascii());

    let single = fig
        .series
        .iter()
        .find(|s| s.name == "single-rail")
        .expect("single-rail series");
    let striped = fig
        .series
        .iter()
        .find(|s| s.name == "4-rail")
        .expect("4-rail series");
    let ramped = fig
        .series
        .iter()
        .find(|s| s.name == "4-rail ramped")
        .expect("4-rail ramped series");

    for &(x, y) in &striped.points {
        let base = single.y_at(x).expect("matching single-rail point");
        let r = ramped.y_at(x).expect("matching ramped point");
        println!(
            "[fig_rail] {x:>10.0} B: 4-rail {y:6.2} GB/s (ramped {r:6.2}) vs single-rail \
             {base:6.2} GB/s ({:.1}x)",
            y / base
        );
        if x >= (1 << 20) as f64 {
            assert!(
                y >= base * 2.0,
                "rail striping under 2x at {x}B: {y} vs {base} GB/s"
            );
        }
    }

    // Ramped first chunks strictly reduce modeled time-to-first-byte at
    // equal total bytes — on the rail stripe *and* the engine stripe.
    let mut params = CostParams::default();
    params.nic.rails = 4;
    let base = CostModel::new(Topology::new(2, 2, 2), params.clone());
    params.stripe.ramp_factor = 0.25;
    let ramp = CostModel::new(Topology::new(2, 2, 2), params);
    let bytes = 4 << 20;
    let (rail_chunk, rail_width) = base.rail_stripe_for(bytes, 1 << 20);
    assert_eq!(
        (rail_chunk, rail_width),
        ramp.rail_stripe_for(bytes, 1 << 20),
        "ramping must not change the planned stripe shape (equal total bytes)"
    );
    let (ttfb_base, ttfb_ramp) = (base.nic_ttfb_ns(rail_chunk), ramp.nic_ttfb_ns(rail_chunk));
    println!(
        "[fig_rail] rail TTFB at chunk {rail_chunk}B: {ttfb_ramp:.0}ns ramped vs \
         {ttfb_base:.0}ns unramped"
    );
    assert!(
        ttfb_ramp < ttfb_base,
        "ramp did not reduce rail time-to-first-byte: {ttfb_ramp} !< {ttfb_base}"
    );
    let (eng_chunk, _) = base.stripe_for(Locality::SameNode, bytes, 1 << 20, usize::MAX);
    assert!(
        ramp.engine_ttfb_ns(eng_chunk, true) < base.engine_ttfb_ns(eng_chunk, true),
        "ramp did not reduce engine time-to-first-byte"
    );

    println!("[fig_rail] 4-rail striping sustains >=2x single-rail remote throughput");
}
