//! Bench: batched command-stream submission (one ring doorbell per
//! plan-group) vs per-op submission. The acceptance bar: per-op proxy
//! overhead for batched small puts must be at least 3× lower than
//! per-op submission at batch depth ≥ 8.
//! `cargo bench --bench fig_batch` (`RISHMEM_SMOKE=1` shrinks nothing —
//! this bench is already tiny).

use rishmem::bench::figures::fig_batch;

fn main() {
    let fig = fig_batch();
    println!("{}", fig.render_ascii());

    let overhead = fig
        .series
        .iter()
        .find(|s| s.name == "per-op submission overhead")
        .expect("overhead series");
    let at = |d: f64| {
        overhead
            .points
            .iter()
            .find(|&&(x, _)| x == d)
            .map(|&(_, y)| y)
            .unwrap_or_else(|| panic!("no point at depth {d}"))
    };

    let per_op = at(1.0);
    for depth in [8.0, 16.0, 32.0] {
        let batched = at(depth);
        println!(
            "[fig_batch] depth {depth:>2}: {batched:8.1} ns/op vs per-op {per_op:8.1} ns/op \
             ({:.1}x lower)",
            per_op / batched
        );
        assert!(
            batched * 3.0 <= per_op,
            "depth {depth}: batched overhead {batched} ns/op not 3x below per-op {per_op} ns/op"
        );
    }
    // Deeper batches must never cost more per op than shallower ones.
    let mut prev = f64::INFINITY;
    for &(x, y) in &overhead.points {
        assert!(y <= prev * 1.001, "per-op overhead rose at depth {x}");
        prev = y;
    }
    println!("[fig_batch] batched submission amortizes the ring doorbell as designed");
}
