//! Bench E7: paper Fig 6 — fcollect_work_group (device store path) vs the
//! host-initiated copy engine, for 4/8/12 PEs.
//! `cargo bench --bench fig6_fcollect`

use rishmem::bench::figures::fig6;

fn main() {
    let mut crossovers = Vec::new();
    for npes in [4usize, 8, 12] {
        let f = fig6(npes);
        println!("{}", f.render_ascii());
        // Small elements counts: device stores beat the host engine for
        // every work-group size (paper: "the kernel-initiated direct store
        // … performs better … for small to medium number of elements").
        for s in f.series.iter().filter(|s| s.name.contains("work-items")) {
            let host = f.series.iter().find(|s| s.name == "host copy-engine").unwrap();
            for &(x, y) in s.points.iter().filter(|(x, _)| *x <= 256.0) {
                let h = host.y_at(x).unwrap();
                assert!(
                    y > h,
                    "fig6-{npes}pe: {} {y} !> host {h} at {x} elems",
                    s.name
                );
            }
        }
        // Record the 256-work-item crossover (paper compares 4PE vs 12PE).
        let x = f.crossover("256 work-items", "host copy-engine");
        crossovers.push((npes, x));
        println!();
    }
    println!("cutover points (256 work-items): {crossovers:?}");
    // Paper Fig 6: with 4 PEs the crossover is ~4K elems; with 12 PEs, 4K
    // elems still favors the store path — i.e. the crossover moves right
    // (or disappears) as npes grows.
    let x4 = crossovers[0].1.unwrap_or(f64::INFINITY);
    let x12 = crossovers[2].1.unwrap_or(f64::INFINITY);
    assert!(
        x12 >= x4,
        "crossover should move right with more PEs: 4PE={x4} 12PE={x12}"
    );
    println!("[fig6] cutover moves right with PE count, as in the paper");
}
