//! Bench E10: the reverse-offload ring in wall-clock — §III-D claims.
//! `cargo bench --bench ring_buffer`

use std::sync::Arc;

use rishmem::bench::measure_wall;
use rishmem::ringbuf::{CompletionPool, Message, Ring, RingOp, COMPLETION_NONE};

fn main() {
    // ---- slot arbitration cost: the single fetch-add ------------------
    let ring = Ring::new(1 << 16);
    let mut consumer = ring.consumer();
    let m = measure_wall(|| {
        ring.send(Message::nop());
        consumer.try_recv();
    });
    println!("send+recv (uncontended):    {:8.1} ns/pair", m.best_ns);

    // ---- blocking round trip through an echo service -------------------
    let echo_ring = Ring::new(256);
    let pool = Arc::new(CompletionPool::new(64));
    let mut echo_consumer = echo_ring.consumer();
    let pool2 = pool.clone();
    let echo = std::thread::spawn(move || loop {
        let msg = echo_consumer.recv();
        if msg.ring_op() == Some(RingOp::Shutdown) {
            return;
        }
        if msg.completion != COMPLETION_NONE {
            pool2.complete(msg.completion, msg.inline_val);
        }
    });
    let m = measure_wall(|| {
        let t = pool.alloc();
        let mut msg = Message::nop();
        msg.completion = t.index;
        echo_ring.send(msg);
        pool.wait(t);
    });
    println!(
        "blocking RTT (echo thread): {:8.1} ns  (paper: ~5 µs over PCIe)",
        m.best_ns
    );
    let mut sd = Message::nop();
    sd.op = RingOp::Shutdown as u8;
    echo_ring.send(sd);
    let _ = echo.join();

    // ---- multi-producer throughput -------------------------------------
    for producers in [1usize, 2, 4, 8] {
        const PER: u64 = 100_000;
        let ring = Ring::new(4096);
        let mut consumer = ring.consumer();
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for _ in 0..producers {
                let r = Arc::clone(&ring);
                s.spawn(move || {
                    for _ in 0..PER {
                        r.send(Message::nop());
                    }
                });
            }
            s.spawn(move || {
                for _ in 0..producers as u64 * PER {
                    consumer.recv();
                }
            });
        });
        let rate = producers as f64 * PER as f64 / t0.elapsed().as_secs_f64();
        println!(
            "throughput {producers} producers: {:8.2} M msg/s  (paper: >20 M req/s on PVC+SPR)",
            rate / 1e6
        );
    }
}
