//! Bench E5/E6: paper Fig 5 — work_group Put with the cutover, under both
//! the `Tuned` (model-argmin) and `Adaptive` (online-learned) modes.
//! The tuned curve must track the upper envelope of Fig 4's two paths,
//! and the adaptive curve must track the tuned one after warm-up.
//! `cargo bench --bench fig5_cutover`

use rishmem::bench::figures::{adaptive_cutover_report, fig4a, fig4b, fig5_adaptive, fig5a, fig5b};

fn main() {
    let tuned = fig5a();
    println!("{}", tuned.render_ascii());
    let lat = fig5b();
    println!("{}", lat.render_ascii());

    let store = fig4a();
    let engine = fig4b();

    // Envelope invariant (paper: "with cutover value set,
    // ishmemx_put_work_group obtains better performance for small to
    // medium message sizes by using direct store … for larger message
    // sizes, after the cutover, it matches the hardware copy engines").
    for name in ["1 work-items", "128 work-items", "1024 work-items"] {
        let t = tuned.series.iter().find(|s| s.name == name).unwrap();
        let s = store.series.iter().find(|s| s.name == name).unwrap();
        let e = engine.series.iter().find(|s| s.name == name).unwrap();
        for &(x, y) in &t.points {
            let best = s.y_at(x).unwrap().max(e.y_at(x).unwrap());
            assert!(
                y >= best * 0.94,
                "{name}: tuned {y} far below envelope {best} at {x}B"
            );
        }
        // And the crossover must move right as the group grows.
    }
    // Latency view: monotone in size for a fixed group.
    for s in &lat.series {
        let mut prev = 0.0;
        for &(x, y) in &s.points {
            assert!(y >= prev * 0.999, "{}: latency dipped at {x}B", s.name);
            prev = y;
        }
    }
    println!("[fig5] tuned cutover tracks the upper envelope of store/engine paths");

    // Same sweep under the adaptive cutover: the measurement warm-up is
    // the online warm-up, so the adaptive curve must track the tuned one.
    let adaptive = fig5_adaptive();
    println!("{}", adaptive.render_ascii());
    for t in &tuned.series {
        let a = adaptive.series.iter().find(|s| s.name == t.name).unwrap();
        for &(x, y) in &a.points {
            let ty = t.y_at(x).unwrap();
            assert!(
                y >= ty * 0.9,
                "{}: adaptive {y} far below tuned {ty} at {x}B",
                t.name
            );
        }
    }
    println!("[fig5] adaptive cutover converged to the tuned envelope");

    // Fig 5 comparison table: learned crossovers vs the tuned model's.
    println!("{}", adaptive_cutover_report());
}
