//! Bench: the L1 Pallas reduce kernel through PJRT vs the native fold —
//! quantifies the kernel-launch overhead behind the
//! `xla_reduce_min_elems` cutover (an ablation of DESIGN.md E12's
//! gradient path). `cargo bench --bench reduce_kernel`

use rishmem::bench::measure_wall;
use rishmem::runtime::{Manifest, XlaRuntime};

fn main() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = XlaRuntime::load_default().expect("runtime");
    let chunk = rt.reduce_chunk_elems();

    let a: Vec<f32> = (0..chunk).map(|i| i as f32 * 0.5).collect();
    let b: Vec<f32> = (0..chunk).map(|i| (chunk - i) as f32).collect();
    let bytes_a: Vec<u8> = a.iter().flat_map(|x| x.to_le_bytes()).collect();
    let bytes_b: Vec<u8> = b.iter().flat_map(|x| x.to_le_bytes()).collect();

    // Warm the executable cache so we measure execution, not compilation.
    let mut acc = bytes_a.clone();
    rt.reduce_fold_bytes("sum", "f32", &mut acc, &bytes_b).unwrap();

    let m_xla = measure_wall(|| {
        let mut acc = bytes_a.clone();
        rt.reduce_fold_bytes("sum", "f32", &mut acc, &bytes_b).unwrap();
    });

    let m_native = measure_wall(|| {
        let mut acc = a.clone();
        for (x, y) in acc.iter_mut().zip(&b) {
            *x += *y;
        }
        std::hint::black_box(&acc);
    });

    let ns_per_elem_xla = m_xla.best_ns / chunk as f64;
    let ns_per_elem_nat = m_native.best_ns / chunk as f64;
    println!("reduce chunk = {chunk} f32 elems");
    println!(
        "  XLA/Pallas kernel: {:9.0} ns/chunk  ({:.3} ns/elem)",
        m_xla.best_ns, ns_per_elem_xla
    );
    println!(
        "  native fold:       {:9.0} ns/chunk  ({:.3} ns/elem)",
        m_native.best_ns, ns_per_elem_nat
    );
    println!(
        "  launch+copy overhead ratio: {:.1}x — this is why ishmem keeps a \
         native fast path below xla_reduce_min_elems",
        m_xla.best_ns / m_native.best_ns
    );

    // Throughput with the pipeline warm, folding many chunks (the
    // gradient-allreduce shape from the train harness).
    let chunks = 16;
    let m_bulk = measure_wall(|| {
        let mut acc = bytes_a.clone();
        for _ in 0..chunks {
            rt.reduce_fold_bytes("sum", "f32", &mut acc, &bytes_b).unwrap();
        }
    });
    let gbs = (chunks * chunk * 4) as f64 / m_bulk.best_ns;
    println!("  bulk fold ({chunks} std chunks): {gbs:.3} GB/s through the PJRT service");

    // §Perf iteration 1: the wide chunk amortizes the launch overhead.
    if let Some(wide) = rt.reduce_wide_elems() {
        let aw: Vec<u8> = (0..wide)
            .flat_map(|i| (i as f32 * 0.5).to_le_bytes())
            .collect();
        let bw: Vec<u8> = (0..wide)
            .flat_map(|i| ((wide - i) as f32).to_le_bytes())
            .collect();
        let mut acc = aw.clone();
        rt.reduce_fold_bytes_wide("sum", "f32", &mut acc, &bw).unwrap();
        let wide_chunks = chunks * chunk / wide; // same total elements
        let m_wide = measure_wall(|| {
            let mut acc = aw.clone();
            for _ in 0..wide_chunks.max(1) {
                rt.reduce_fold_bytes_wide("sum", "f32", &mut acc, &bw).unwrap();
            }
        });
        let gbs_wide = (wide_chunks.max(1) * wide * 4) as f64 / m_wide.best_ns;
        println!(
            "  bulk fold (wide {wide}-elem chunks): {gbs_wide:.3} GB/s  \
             ({:.1}x over std — §Perf iteration 1)",
            gbs_wide / gbs
        );
    }
}
