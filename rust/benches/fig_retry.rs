//! Bench: end-to-end transfer reliability (ISSUE 9). Remote blocking
//! puts run under scripted transient chunk faults (~5% drops, ~5%
//! forced corruption) with the checksum/replay layer on. Acceptance
//! bars:
//! (a) every payload reads back bit-identical under faults,
//! (b) the modeled cost of the faulty runs exceeds the clean run by
//!     exactly the retry-cost model — total backoff plus one ring
//!     doorbell per NACK round,
//! (c) the attempt histogram reproduces both the NACK count and the
//!     backoff total from the configured exponential schedule,
//! (d) `retry.enable = false` and `retry.enable = true` are bit-for-bit
//!     identical over clean lanes (checksums charge no modeled time),
//! (e) a put against a permanently-dropping lane unwinds with a
//!     structured `DegradedError` well inside `xfer.op_timeout_ms`
//!     instead of hanging.
//! `cargo bench --bench fig_retry` (`RISHMEM_SMOKE=1` shrinks the sweep).

use rishmem::bench::figures::{retry_exhaustion_probe, retry_scenarios, RetryScenario};
use rishmem::bench::Figure;
use rishmem::ishmem::RetryConfig;
use rishmem::sim::DegradedKind;
use rishmem::xfer::stream::retry_backoff_ns;

/// Replays the two integer identities the replay loop must satisfy:
/// one NACK round per attempt level, and the backoff total as priced by
/// the configured exponential schedule.
fn check_histogram_identities(sc: &RetryScenario, rcfg: &RetryConfig) {
    let hist = &sc.attempt_hist;
    let nacks: u64 = hist.iter().enumerate().map(|(a, &n)| a as u64 * n).sum();
    assert_eq!(
        sc.snapshot.retry_nacks, nacks,
        "{}: NACK rounds do not match the attempt histogram ({hist:?})",
        sc.series.name
    );
    let backoff: u64 = hist
        .iter()
        .enumerate()
        .map(|(a, &n)| n * (1..=a as u32).map(|k| retry_backoff_ns(rcfg, k)).sum::<u64>())
        .sum();
    assert_eq!(
        sc.snapshot.retry_backoff_ns_total, backoff,
        "{}: backoff total does not match the schedule priced over {hist:?}",
        sc.series.name
    );
}

/// The modeled-cost identity: a faulty sweep costs exactly the clean
/// sweep plus total backoff plus one ring doorbell per NACK round.
fn check_cost_identity(faulty: &RetryScenario, clean: &RetryScenario) {
    let extra = faulty.snapshot.retry_backoff_ns_total as f64
        + faulty.snapshot.retry_nacks as f64 * faulty.ring_post_ns;
    let delta = faulty.modeled_ns - clean.modeled_ns;
    let rel = (delta - extra).abs() / extra.max(1.0);
    println!(
        "[fig_retry] {}: modeled delta {delta:.0} ns vs retry-cost model {extra:.0} ns \
         ({} nacks, {} replays)",
        faulty.series.name, faulty.snapshot.retry_nacks, faulty.snapshot.retry_replays
    );
    assert!(
        rel <= 1e-3,
        "{}: modeled cost diverges from the retry-cost model: delta {delta} ns vs \
         modeled {extra} ns ({:.4}% off)",
        faulty.series.name,
        rel * 100.0
    );
}

fn main() {
    let scenarios = retry_scenarios();
    let mut fig = Figure::new(
        "fig-retry",
        "transfer reliability: goodput under transient chunk faults",
        "msg size",
        "GB/s",
    );
    for sc in &scenarios {
        fig.series.push(sc.series.clone());
    }
    println!("{}", fig.render_ascii());

    let by_name = |name: &str| {
        scenarios
            .iter()
            .find(|s| s.series.name == name)
            .unwrap_or_else(|| panic!("missing scenario {name:?}"))
    };
    let off_clean = by_name("retry-off-clean");
    let on_clean = by_name("retry-on-clean");
    let dropped = by_name("drop-5pct");
    let corrupted = by_name("corrupt-5pct");

    // (a) payload bit-identity everywhere, faults or not.
    for sc in &scenarios {
        assert!(sc.payloads_ok, "{}: a payload read back corrupted", sc.series.name);
    }

    // (d) retry on over clean lanes is bit-for-bit the retry-off baseline.
    assert_eq!(
        on_clean.series.points, off_clean.series.points,
        "enabling retry changed clean-lane goodput — checksum stamping must be free"
    );
    for sc in [off_clean, on_clean] {
        assert_eq!(sc.snapshot.retry_nacks, 0, "{}: spurious NACKs", sc.series.name);
        assert_eq!(sc.snapshot.retry_replays, 0, "{}: spurious replays", sc.series.name);
        assert_eq!(sc.snapshot.fault_dropped_chunks, 0, "{}: spurious drops", sc.series.name);
    }

    // The scripted windows actually fired and were recovered from.
    assert!(dropped.snapshot.fault_dropped_chunks > 0, "drop window never fired");
    assert!(dropped.snapshot.retry_replays > 0, "dropped chunks were never replayed");
    assert!(corrupted.snapshot.fault_corrupted_chunks > 0, "corrupt window never fired");
    assert!(
        corrupted.snapshot.retry_checksum_fail > 0,
        "forced corruption never failed a checksum"
    );
    assert!(corrupted.snapshot.retry_replays > 0, "corrupted chunks were never replayed");
    for sc in [dropped, corrupted] {
        assert_eq!(sc.snapshot.retry_exhausted, 0, "{}: replay budget blown", sc.series.name);
    }

    // (b) + (c): cost-model and histogram identities.
    let rcfg = RetryConfig { enable: true, ..Default::default() };
    for sc in [dropped, corrupted] {
        check_histogram_identities(sc, &rcfg);
        check_cost_identity(sc, on_clean);
    }

    // (e) exhaustion: a permanently-dropping lane must surface a
    // structured error promptly, not hang the blocking put.
    let (err, waited_ms) = retry_exhaustion_probe();
    let err = err.expect("put against a dead lane completed instead of degrading");
    assert_eq!(
        err.kind,
        DegradedKind::RetryExhausted,
        "wrong degraded kind from an exhausted replay budget: {err}"
    );
    assert!(
        waited_ms < 2_000,
        "exhaustion took {waited_ms} ms — the op deadline (2000 ms) should never be \
         the limiting factor when the proxy is NACKing promptly"
    );
    println!("[fig_retry] exhaustion probe degraded in {waited_ms} ms: {err}");

    println!(
        "[fig_retry] payloads bit-identical under ~5% chunk loss; goodput delta matches \
         the retry-cost model; clean-lane behavior unchanged by retry.enable"
    );
}
