//! Bench: fully offloaded progress — stream-ordered triggered chains
//! (ISSUE 10). Depth-*d* dependent programs (d−1 ordered puts then a
//! signal add) run fused (`chain.enable`) and sequential (the default)
//! against a zero-program control that measures the fixed launch
//! overhead. Acceptance bars:
//! (a) a fused depth-*d* chain is exactly ONE doorbell: the fused run's
//!     ring-message count over the control equals the program count,
//! (b) host crossings drop ≥2× vs the sequential spelling from depth 3,
//! (c) landed payloads are bit-identical fused vs sequential (and match
//!     the expected last-program pattern),
//! (d) the chain metrics account exactly: one submission per program,
//!     depth−1 reclaimed doorbells each, nothing flushed unfusable, and
//!     a sequential machine counts no chains at all,
//! (e) the fused program loop is modeled strictly cheaper than the
//!     sequential one (the fuse-vs-flush pricing must be a real win).
//! `cargo bench --bench fig_chain` (`RISHMEM_SMOKE=1` shrinks the sweep).

use rishmem::bench::figures::{
    chain_depth_sweep, chain_pattern, chain_scenarios, CHAIN_STAGE_BYTES,
};
use rishmem::bench::{Figure, Series};

fn main() {
    let scenarios = chain_scenarios();
    let control = scenarios[0].ring_messages;

    let mut fig = Figure::new(
        "fig-chain",
        "triggered chains: host crossings per dependent program vs depth",
        "chain depth",
        "ring msgs / program",
    );
    let mut fused_series = Series::new("fused");
    let mut seq_series = Series::new("sequential");
    for sc in &scenarios[1..] {
        let per = sc.ring_messages.saturating_sub(control) as f64 / sc.programs.max(1) as f64;
        if sc.name.starts_with("fused") {
            fused_series.push(sc.depth as f64, per);
        } else {
            seq_series.push(sc.depth as f64, per);
        }
    }
    fig.series.push(fused_series);
    fig.series.push(seq_series);
    println!("{}", fig.render_ascii());

    let by_name = |name: &str| {
        scenarios
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing scenario {name:?}"))
    };

    for d in chain_depth_sweep() {
        let fused = by_name(&format!("fused-d{d}"));
        let seq = by_name(&format!("seq-d{d}"));
        let n = fused.programs as u64;
        let fused_msgs = fused.ring_messages - control;
        let seq_msgs = seq.ring_messages - control;
        println!(
            "[fig_chain] depth {d}: {n} programs — fused {fused_msgs} crossings, \
             sequential {seq_msgs} crossings, modeled {:.0} vs {:.0} ns",
            fused.modeled_ns, seq.modeled_ns
        );

        // (a) the single-doorbell identity, exact: one ring message per
        // fused program beyond the fixed launch overhead.
        assert_eq!(
            fused_msgs, n,
            "depth {d}: a fused chain must be exactly one doorbell"
        );

        // (b) host-crossing reduction: strictly fewer always, ≥2× from
        // depth 3 (the sequential spelling pays ~one crossing per stage).
        assert!(
            fused_msgs < seq_msgs,
            "depth {d}: fusion did not reduce host crossings ({fused_msgs} vs {seq_msgs})"
        );
        if d >= 3 {
            assert!(
                seq_msgs >= 2 * fused_msgs,
                "depth {d}: expected ≥2× fewer host crossings, got {fused_msgs} vs {seq_msgs}"
            );
        }

        // (c) bit-identical results, and they are the right bytes.
        assert_eq!(
            fused.landed, seq.landed,
            "depth {d}: fused and sequential landed different bytes"
        );
        let len = CHAIN_STAGE_BYTES;
        for s in 0..d - 1 {
            assert_eq!(
                fused.landed[s * len..(s + 1) * len],
                chain_pattern(fused.programs - 1, s, len)[..],
                "depth {d} stage {s}: landed bytes are not the last program's pattern"
            );
        }

        // (d) exact chain accounting on both machines.
        assert_eq!(fused.snapshot.chain_submitted, n, "depth {d}: {:?}", fused.snapshot);
        assert_eq!(
            fused.snapshot.chain_fused_doorbells,
            n * (d as u64 - 1),
            "depth {d}: reclaimed-doorbell ledger wrong"
        );
        assert_eq!(
            fused.snapshot.chain_flushed_unfusable, 0,
            "depth {d}: a fusable chain was flushed sequentially"
        );
        assert!(fused.snapshot.chain_triggered >= n * (d as u64 - 1), "depth {d}");
        assert_eq!(
            (seq.snapshot.chain_submitted, seq.snapshot.chain_fused_doorbells),
            (0, 0),
            "depth {d}: a chain-disabled machine counted chains"
        );

        // (e) fusion is a modeled win, not just a message-count win.
        assert!(
            fused.modeled_ns < seq.modeled_ns,
            "depth {d}: fused program loop modeled no cheaper ({:.0} vs {:.0} ns)",
            fused.modeled_ns,
            seq.modeled_ns
        );
    }

    println!(
        "[fig_chain] every fused depth-d chain submitted with one doorbell; ≥2× fewer \
         host crossings from depth 3; payloads bit-identical fused vs sequential"
    );
}
