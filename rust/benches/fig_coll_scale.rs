//! Bench: topology-aware hierarchical collectives (ISSUE 7). The cost
//! model prices flat vs hierarchical-ring/tree broadcast, fcollect and
//! allreduce across `Topology::multi_node_for` machines; acceptance bars:
//!
//! (a) the best hierarchical schedule beats flat by ≥2× on every ≥64-PE
//!     machine at ≥1 MiB, and the advantage never shrinks as PE count
//!     grows (and strictly grows for broadcast, whose flat wire term is
//!     linear in remote peers);
//! (b) a real 64-PE machine under `coll.algo = Auto` picks the hierarchy
//!     and its modeled broadcast time beats the same machine forced flat;
//! (c) single-node machines are untouched: the estimator returns
//!     bit-identical times for all three algorithms, and a real
//!     single-node run under Auto matches forced-flat bit for bit.
//!
//! `cargo bench --bench fig_coll_scale` (`RISHMEM_SMOKE=1` shrinks it).

use rishmem::bench::figures::{coll_scale_sweep, fig_coll_scale};
use rishmem::bench::measure_fixed;
use rishmem::sim::cost::CostParams;
use rishmem::sim::{CollOp, CollShape, CostModel};
use rishmem::{CollAlgoMode, CollConfig, Ishmem, IshmemConfig, TeamId, Topology};

/// Modeled best time of one 1 MiB broadcast on a real machine with the
/// given algorithm mode (every PE participates; PE 0's clock reports).
fn machine_broadcast_ns(topo: Topology, algo: CollAlgoMode) -> f64 {
    let cfg = IshmemConfig {
        topology: topo,
        heap_bytes: 4 << 20,
        coll: CollConfig { algo, leader_fanout: 4, ..CollConfig::default() },
        ..Default::default()
    };
    let ish = Ishmem::new(cfg).expect("fig_coll_scale machine");
    let times = ish.launch(|ctx| {
        let dest = ctx.calloc::<u8>(1 << 20);
        let src = ctx.calloc::<u8>(1 << 20);
        ctx.barrier_all();
        let m = measure_fixed(&ctx.clock, 1, 2, || {
            ctx.broadcast(dest, src, 1 << 20, 0, TeamId::WORLD);
        });
        (ctx.pe() == 0).then_some(m.best_ns)
    });
    let hier = ish.metrics.snapshot().coll_hier;
    ish.shutdown();
    match algo {
        CollAlgoMode::Flat => assert_eq!(hier, 0, "forced flat ran hierarchical"),
        CollAlgoMode::Auto => {}
        _ => assert!(hier > 0, "forced hierarchy ran flat"),
    }
    times.into_iter().flatten().next().expect("pe 0 measurement")
}

fn main() {
    let fig = fig_coll_scale();
    println!("{}", fig.render_ascii());

    // (a) Estimator sweep: every op, ≥1 MiB, across the PE sweep.
    let sweep = coll_scale_sweep();
    for op in [CollOp::Broadcast, CollOp::Fcollect, CollOp::Reduce] {
        for &bytes in &[1usize << 20, 4 << 20] {
            let mut last_ratio = 0.0f64;
            let mut first_ratio = f64::NAN;
            for &npes in &sweep {
                let topo = Topology::multi_node_for(npes);
                let shape = CollShape::from_members(&topo, 0..npes);
                let cost = CostModel::new(topo, CostParams::default());
                let est = cost.coll_estimates(&shape, op, bytes, 4);
                let (algo, hier_ns) = est.best_hier();
                let ratio = est.flat_ns / hier_ns;
                println!(
                    "[fig_coll_scale] {op:?} {bytes:>8} B {npes:>5} PEs: flat \
                     {:8.2} ms vs {algo:?} {:8.2} ms  ({ratio:.1}x)",
                    est.flat_ns / 1e6,
                    hier_ns / 1e6
                );
                assert!(
                    ratio >= 2.0,
                    "{op:?}: hierarchy under 2x at {npes} PEs / {bytes} B: {ratio:.2}x"
                );
                assert!(
                    ratio >= last_ratio * 0.999,
                    "{op:?}: advantage shrank at {npes} PEs / {bytes} B: \
                     {ratio:.2}x after {last_ratio:.2}x"
                );
                if first_ratio.is_nan() {
                    first_ratio = ratio;
                }
                last_ratio = ratio;
            }
            if op == CollOp::Broadcast {
                assert!(
                    last_ratio > first_ratio,
                    "broadcast advantage must grow with PE count: \
                     {first_ratio:.2}x -> {last_ratio:.2}x"
                );
            }
        }
    }

    // (c) Single-node estimates: all three algorithms are bit-identical.
    let topo = Topology::new(1, 4, 2);
    let shape = CollShape::from_members(&topo, 0..topo.npes());
    let cost = CostModel::new(topo, CostParams::default());
    for op in [CollOp::Broadcast, CollOp::Fcollect, CollOp::Reduce] {
        let est = cost.coll_estimates(&shape, op, 1 << 20, 4);
        assert_eq!(est.flat_ns.to_bits(), est.ring_ns.to_bits(), "{op:?}");
        assert_eq!(est.flat_ns.to_bits(), est.tree_ns.to_bits(), "{op:?}");
    }
    let auto1 = machine_broadcast_ns(Topology::new(1, 2, 2), CollAlgoMode::Auto);
    let flat1 = machine_broadcast_ns(Topology::new(1, 2, 2), CollAlgoMode::Flat);
    assert_eq!(
        auto1.to_bits(),
        flat1.to_bits(),
        "single-node Auto must reproduce the flat schedule exactly: \
         {auto1} vs {flat1} ns"
    );
    println!("[fig_coll_scale] single-node: Auto == forced-flat bitwise ({auto1:.0} ns)");

    // (b) Real 64-PE machine: Auto picks the hierarchy and beats flat.
    let auto64 = machine_broadcast_ns(Topology::multi_node_for(64), CollAlgoMode::Auto);
    let flat64 = machine_broadcast_ns(Topology::multi_node_for(64), CollAlgoMode::Flat);
    println!(
        "[fig_coll_scale] 64-PE machine: auto {:.2} ms vs forced-flat {:.2} ms ({:.1}x)",
        auto64 / 1e6,
        flat64 / 1e6,
        flat64 / auto64
    );
    assert!(
        auto64 < flat64,
        "hierarchical execution no faster than flat on 64 PEs: {auto64} vs {flat64} ns"
    );

    println!("[fig_coll_scale] hierarchical collectives >=2x flat from 64 PEs, growing with scale");
}
