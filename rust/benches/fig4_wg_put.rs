//! Bench E3/E4: paper Fig 4 — work_group Put on the store path (a) and
//! the copy-engine path (b). `cargo bench --bench fig4_wg_put`

use rishmem::bench::figures::{fig4a, fig4b};

fn main() {
    let a = fig4a();
    println!("{}", a.render_ascii());
    // Fig 4(a) shape: more work-items ⇒ more bandwidth, at every size ≥1KB.
    let names = ["1 work-items", "16 work-items", "128 work-items", "1024 work-items"];
    for w in names.windows(2) {
        let lo = a.series.iter().find(|s| s.name == w[0]).unwrap();
        let hi = a.series.iter().find(|s| s.name == w[1]).unwrap();
        for &(x, y_lo) in lo.points.iter().filter(|(x, _)| *x >= 1024.0) {
            let y_hi = hi.y_at(x).unwrap();
            assert!(
                y_hi >= y_lo * 0.999,
                "fig4a: {} ({y_hi}) < {} ({y_lo}) at {x}B",
                w[1],
                w[0]
            );
        }
    }
    println!("[fig4a] work-group scaling invariant holds\n");

    let b = fig4b();
    println!("{}", b.render_ascii());
    // Fig 4(b) shape: engine path is work-group invariant — all series
    // identical (a single leader item posts the offload).
    let base = &b.series[0];
    for s in &b.series[1..] {
        for &(x, y) in &base.points {
            let y2 = s.y_at(x).unwrap();
            assert!(
                (y - y2).abs() / y.max(1e-9) < 1e-6,
                "fig4b: series diverge at {x}B: {y} vs {y2}"
            );
        }
    }
    println!(
        "[fig4b] engine path is work-group invariant \
         (paper: 'same performance for different number of work-items')"
    );
}
