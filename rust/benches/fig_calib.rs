//! Bench: closed-loop cost-model calibration (ISSUE 5). A synthetic
//! ground-truth hardware model (single-engine fraction 2× the config,
//! rail fraction half, startups off by 25–50%) streams per-(lane,
//! size-class) wall-time observations through the calibrator. Acceptance
//! bars: (a) learned `single_engine_frac` and `rail_bw_frac` land within
//! 10% of the planted truth, (b) the per-class residual wall-vs-model
//! error shrinks (near-)monotonically round over round and ends far below
//! the uncalibrated baseline, (c) a `calib.enable = false` machine's
//! ModelParams never move.
//! `cargo bench --bench fig_calib` (`RISHMEM_SMOKE=1` shrinks the sweep).

use rishmem::bench::figures::{calibration_report, calibration_run};
use rishmem::sim::cost::{CostModel, CostParams};
use rishmem::sim::{Locality, Topology};
use rishmem::xfer::{CalibConfig, Calibrator};

fn main() {
    println!("{}", calibration_report());
    let run = calibration_run();

    // (a) Learned fractions within 10% of the planted ground truth.
    let frac_err = (run.learned.single_engine_frac - run.truth_engine_frac).abs()
        / run.truth_engine_frac;
    assert!(
        frac_err < 0.10,
        "learned single_engine_frac {} not within 10% of planted {}",
        run.learned.single_engine_frac,
        run.truth_engine_frac
    );
    let rail_err =
        (run.learned.rail_bw_frac - run.truth_rail_frac).abs() / run.truth_rail_frac;
    assert!(
        rail_err < 0.10,
        "learned rail_bw_frac {} not within 10% of planted {}",
        run.learned.rail_bw_frac,
        run.truth_rail_frac
    );

    // (b) Residuals shrink monotonically (tiny numerical slack) and end
    // far below the uncalibrated baseline.
    let r = &run.round_residuals;
    assert!(r.len() >= 2, "need at least two rounds: {r:?}");
    for w in r.windows(2) {
        assert!(
            w[1] <= w[0] * 1.01 + 1e-9,
            "residual grew between rounds: {r:?}"
        );
    }
    let last = *r.last().unwrap();
    assert!(
        last < run.baseline_residual * 0.5,
        "calibrated residual {last} did not shrink vs uncalibrated baseline {}",
        run.baseline_residual
    );
    assert!(last < 0.10, "calibrated residual did not converge: {r:?}");
    println!(
        "[fig_calib] residual {:.4} -> {:.4} (uncalibrated baseline {:.4})",
        r[0], last, run.baseline_residual
    );

    // (c) The disabled-calibration discipline: observations are dropped,
    // the version never moves, the params stay bit-identical.
    let cost = CostModel::new(Topology::new(2, 2, 2), CostParams::default());
    let before = cost.model.get();
    let off = Calibrator::new(cost.clone(), CalibConfig::default());
    for _ in 0..100 {
        off.observe_engine(Locality::SameNode, 4 << 20, true, 1.0e6);
        off.observe_rail(0, 0, 4 << 20, 1.0e6);
    }
    off.refine_cl_boundary();
    assert_eq!(cost.model.version(), 0, "disabled calibration moved the model");
    assert_eq!(
        cost.model.get().single_engine_frac.to_bits(),
        before.single_engine_frac.to_bits()
    );

    println!(
        "[fig_calib] learned frac {:.4} / rail frac {:.4} within 10% of planted truth",
        run.learned.single_engine_frac, run.learned.rail_bw_frac
    );
}
