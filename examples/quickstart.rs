//! Quickstart: a 12-PE simulated Aurora node doing the OpenSHMEM basics —
//! symmetric allocation, put/get, atomics, barrier, reduction.
//!
//! Run: `cargo run --release --example quickstart`

use rishmem::{run_npes, Cmp, ReduceOp, TeamId};

fn main() -> anyhow::Result<()> {
    let npes = 12;
    println!("== rishmem quickstart: {npes} PEs ==");

    let reports = run_npes(npes, |ctx| {
        let me = ctx.pe();
        let n = ctx.npes();

        // --- symmetric allocation (collective) --------------------------
        let ring_buf = ctx.calloc::<u64>(16);
        let counter = ctx.calloc::<u64>(1);
        let flag = ctx.calloc::<u64>(1);

        // --- one-sided put around a ring ---------------------------------
        let data: Vec<u64> = (0..16).map(|i| (me * 100 + i) as u64).collect();
        ctx.put(ring_buf, &data, (me + 1) % n);
        ctx.barrier_all();
        let left = (me + n - 1) % n;
        let got = ctx.read_local_vec(ring_buf);
        assert_eq!(got[7], (left * 100 + 7) as u64);

        // --- atomics: everyone bumps PE 0's counter ----------------------
        ctx.atomic_add(counter, 1u64, 0);
        ctx.barrier_all();
        if me == 0 {
            assert_eq!(ctx.atomic_fetch(counter, 0), n as u64);
        }

        // --- point-to-point sync: PE 0 releases everyone ------------------
        if me == 0 {
            for pe in 0..n {
                ctx.atomic_set(flag, 1u64, pe);
            }
        }
        ctx.wait_until(flag, Cmp::Eq, 1u64);

        // --- reduction: sum of squares across the team --------------------
        let dest = ctx.calloc::<i64>(8);
        let src = ctx.calloc::<i64>(8);
        let mine: Vec<i64> = (0..8).map(|i| (me * me + i) as i64).collect();
        ctx.write_local(src, &mine);
        ctx.reduce(dest, src, 8, ReduceOp::Sum, TeamId::WORLD);
        let sums = ctx.read_local_vec(dest);

        // Report modeled device time spent by this PE.
        (sums[0], ctx.clock.now_ns())
    })?;

    let expect: i64 = (0..npes as i64).map(|r| r * r).sum();
    for (pe, (sum, ns)) in reports.iter().enumerate() {
        assert_eq!(*sum, expect, "pe {pe} reduce mismatch");
        println!("PE {pe:2}: Σ r² = {sum} | modeled device time {:.1} µs", ns / 1000.0);
    }
    println!("quickstart OK — all {npes} PEs agreed on Σ r² = {expect}");
    Ok(())
}
