//! The `ze_peer` baseline as a standalone tool (paper §IV [3]): raw
//! Level-Zero copy-engine bandwidth between device pairs, no SHMEM
//! library in the path.
//!
//! Run: `cargo run --release --example ze_peer`

use rishmem::bench::report::Figure;
use rishmem::bench::size_sweep;
use rishmem::bench::zepeer::{zepeer_read_series, zepeer_write_series};
use rishmem::Topology;

fn main() {
    let topo = Topology::new(1, 2, 2);
    let sizes = size_sweep();

    let mut fig = Figure::new(
        "ze_peer",
        "ze_peer: copy-engine read/write bandwidth",
        "msg size",
        "GB/s",
    );
    for (name, target) in [("same-tile", 0usize), ("cross-tile", 1), ("cross-GPU", 2)] {
        fig.series.push(zepeer_write_series(
            &topo,
            0,
            target,
            &sizes,
            &format!("write {name}"),
        ));
        fig.series.push(zepeer_read_series(
            &topo,
            0,
            target,
            &sizes,
            &format!("read {name}"),
        ));
    }
    println!("{}", fig.render_ascii());
}
