//! End-to-end driver (DESIGN.md E12): data-parallel transformer training
//! where every layer of the stack is on the path —
//!
//!   * L2: the AOT-lowered JAX transformer `train_step` runs per PE via
//!     PJRT (CPU client, artifacts from `make artifacts`);
//!   * L3: gradients cross PEs through `ishmem_reduce` on the simulated
//!     node (push collectives, symmetric heap, real proxy threads);
//!   * L1: full 8192-element chunks of that reduction execute the Pallas
//!     reduce kernel.
//!
//! Run: `cargo run --release --example train_dataparallel -- [steps] [pes] [model]`
//! Defaults reproduce the EXPERIMENTS.md E12 run: 200 steps, 4 PEs, small
//! (~470K params; `base100m` exists in python/compile/model.py but is not
//! trainable on a 1-core CI substrate — see DESIGN.md §7).

use rishmem::train::{train_data_parallel, TrainConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps = args.first().map_or(Ok(200), |s| s.parse())?;
    let pes = args.get(1).map_or(Ok(4), |s| s.parse())?;
    let model = args.get(2).cloned().unwrap_or_else(|| "small".into());

    let cfg = TrainConfig {
        model,
        pes,
        steps,
        lr: 0.5,
        seed: 42,
        log_every: 10,
        eval_every: 50,
    };
    println!(
        "== e2e data-parallel training: {} | {} PEs | {} steps ==",
        cfg.model, cfg.pes, cfg.steps
    );
    let r = train_data_parallel(&cfg)?;

    println!("\nloss curve (mean across PEs):");
    for (s, l) in &r.losses {
        let bar = "#".repeat((l * 12.0) as usize);
        println!("  step {s:5} {l:8.4} {bar}");
    }
    if !r.eval_losses.is_empty() {
        println!("held-out eval:");
        for (s, l) in &r.eval_losses {
            println!("  step {s:5} {l:8.4}");
        }
    }
    println!(
        "\n{} params | {} tokens/step | {:.1}s wall ({:.1} tok/s) | {} Pallas reduce-kernel calls",
        r.param_count,
        r.tokens_per_step,
        r.wall_seconds,
        r.tokens_per_step as f64 * cfg.steps as f64 / r.wall_seconds,
        r.xla_reduce_calls,
    );
    anyhow::ensure!(
        r.final_loss < r.first_loss,
        "loss did not decrease: {} -> {}",
        r.first_loss,
        r.final_loss
    );
    println!(
        "training learned structure: loss {:.4} -> {:.4}",
        r.first_loss, r.final_loss
    );
    Ok(())
}
