//! RMA bandwidth survey (the paper's Fig 3 scenario as an application):
//! sweeps put/get across the three intra-node hardware paths and prints
//! where the tuned cutover lands.
//!
//! Run: `cargo run --release --example rma_bandwidth`

use rishmem::bench::figures::{fig3a, fig3b};

fn main() -> anyhow::Result<()> {
    for fig in [fig3a(), fig3b()] {
        println!("{}", fig.render_ascii());
        if let Some(x) = fig.crossover("ishmem cross-GPU", "ze_peer cross-GPU") {
            println!(
                "tuned ishmem falls behind the raw engine at {} (reverse-offload latency), \
                 as in the paper's Fig 3\n",
                rishmem::util::fmt_bytes(x as usize)
            );
        }
    }
    Ok(())
}
