//! Reverse-offload ring stress tool: measures the §III-D claims on the
//! *real* lock-free ring in wall-clock — request throughput vs producer
//! count and blocking round-trip time.
//!
//! Run: `cargo run --release --example ring_stress`

use rishmem::bench::figures::ring_figure;

fn main() {
    let fig = ring_figure();
    println!("{}", fig.render_ascii());
    println!(
        "paper §III-D (real PVC+SPR hardware): ~5 µs RTT, >20 M req/s with \
         a single host service thread. This box has one CPU core, so the \
         throughput figure is producer-contended; see EXPERIMENTS.md E10."
    );
}
