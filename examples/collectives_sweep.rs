//! Collective-operation survey (the paper's Fig 6/7 scenarios): fcollect
//! and broadcast across work-group sizes and PE counts, with the
//! host-initiated copy-engine baseline.
//!
//! Run: `cargo run --release --example collectives_sweep [npes]`

use rishmem::bench::figures::{fig6, fig7a, fig7b};

fn main() -> anyhow::Result<()> {
    let npes: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(12);

    let f6 = fig6(npes);
    println!("{}", f6.render_ascii());
    // Where does the biggest work-group stop beating the host engine?
    if let Some(x) = f6.crossover("1024 work-items", "host copy-engine") {
        println!(
            "device store path loses to the host engine at {x} elements \
             (cutover point, paper Fig 6)\n"
        );
    } else {
        println!(
            "device store path wins everywhere on this sweep — more PEs push \
             the cutover right (paper: 12 PEs @ 4K elems still favor stores)\n"
        );
    }

    println!("{}", fig7a().render_ascii());
    println!("{}", fig7b().render_ascii());
    Ok(())
}
