"""Layer-2: JAX transformer LM (fwd/bwd) for the rishmem dist-train example.

The paper (Intel SHMEM) is a communication library; the system-prompt e2e
requirement is a small distributed training run that pushes gradients through
the library.  This module defines the compute side: a decoder-only
transformer whose MLP blocks call the L1 Pallas ``fused_mlp`` kernel, plus a
``train_step`` that returns (loss, grads...).  The Rust coordinator owns the
data-parallel loop: it executes ``train_step`` via PJRT on every PE, all-
reduces the gradient arrays with ``ishmem_reduce`` (which itself runs the AOT
Pallas reduce kernel), and applies SGD.

Everything here is AOT-lowered once by ``aot.py``; Python never runs on the
training request path.
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_mlp import fused_mlp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int
    batch: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


#: tiny — fast pytest config; small — the e2e example config;
#: base100m — the paper-scale config (AOT-able, too slow to *train* on the
#: 1-core CI substrate; see EXPERIMENTS.md E12 for the measured run).
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=64, d_model=32, n_heads=2, n_layers=1,
                        seq_len=16, batch=2),
    "small": ModelConfig("small", vocab=512, d_model=128, n_heads=4,
                         n_layers=2, seq_len=64, batch=4),
    "base100m": ModelConfig("base100m", vocab=32768, d_model=768, n_heads=12,
                            n_layers=12, seq_len=512, batch=8),
}


# ------------------------------------------------------------- parameters --

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical flat (name, shape) list — the AOT calling convention.

    The Rust runtime reproduces this ordering from artifacts/manifest.json;
    any change here is a breaking ABI change for the artifacts.
    """
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        spec += [
            (p + "ln1_scale", (cfg.d_model,)),
            (p + "ln1_bias", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_scale", (cfg.d_model,)),
            (p + "ln2_bias", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    spec += [
        ("lnf_scale", (cfg.d_model,)),
        ("lnf_bias", (cfg.d_model,)),
    ]
    return spec


def param_count(cfg: ModelConfig) -> int:
    n = 0
    for _, shape in param_spec(cfg):
        c = 1
        for s in shape:
            c *= s
        n += c
    return n


def init_params(seed, cfg: ModelConfig) -> List[jnp.ndarray]:
    """Deterministic init from an int32 seed scalar (AOT-lowered as-is)."""
    key = jax.random.PRNGKey(seed)
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    params = []
    for k, (name, shape) in zip(keys, spec):
        base = name.split(".")[-1]
        if base.startswith("ln") or base in ("b1", "b2"):
            if "scale" in base:
                params.append(jnp.ones(shape, jnp.float32))
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 0.02 if "emb" in base else (1.0 / jnp.sqrt(fan_in))
            params.append(std * jax.random.normal(k, shape, jnp.float32))
    return params


def _unflatten(params: List[jnp.ndarray], cfg: ModelConfig):
    names = [n for n, _ in param_spec(cfg)]
    return dict(zip(names, params))


# ----------------------------------------------------------------- layers --

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(x, wq, wk, wv, wo, cfg: ModelConfig):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split(w):
        return (x @ w).reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q, k, v = split(wq), split(wk), split(wv)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ wo


def _mlp(x, w1, b1, w2, b2):
    """MLP block — flattens tokens and calls the Pallas fused kernel."""
    b, s, d = x.shape
    out = fused_mlp(x.reshape(b * s, d), w1, b1, w2, b2)
    return out.reshape(b, s, d)


def forward(params: List[jnp.ndarray], tokens, cfg: ModelConfig):
    """tokens: (batch, seq) int32 -> logits (batch, seq, vocab)."""
    p = _unflatten(params, cfg)
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :, :]
    for l in range(cfg.n_layers):
        q = f"layer{l}."
        a = _layer_norm(x, p[q + "ln1_scale"], p[q + "ln1_bias"])
        x = x + _attention(a, p[q + "wq"], p[q + "wk"], p[q + "wv"],
                           p[q + "wo"], cfg)
        m = _layer_norm(x, p[q + "ln2_scale"], p[q + "ln2_bias"])
        x = x + _mlp(m, p[q + "w1"], p[q + "b1"], p[q + "w2"], p[q + "b2"])
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    return x @ p["tok_emb"].T  # tied output head


def loss_fn(params, tokens, cfg: ModelConfig):
    """Next-token cross entropy over the shifted sequence."""
    logits = forward(params, tokens, cfg)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


def make_train_step(cfg: ModelConfig):
    """(params..., tokens) -> (loss, grads...) — the AOT entry point."""

    def train_step(*args):
        params = list(args[:-1])
        tokens = args[-1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, tokens, cfg))(params)
        return (loss, *grads)

    return train_step


def make_eval_loss(cfg: ModelConfig):
    """(params..., tokens) -> (loss,) — AOT'd for held-out eval."""

    def eval_loss(*args):
        params = list(args[:-1])
        tokens = args[-1]
        return (loss_fn(params, tokens, cfg),)

    return eval_loss


def example_args(cfg: ModelConfig):
    """ShapeDtypeStructs matching the train_step calling convention."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_spec(cfg)]
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32))
    return tuple(specs)
