"""AOT pipeline: lower every L1 kernel and L2 model entry point to HLO text.

Usage (normally via ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts [--models tiny,small]

Emits, into --out-dir:
  reduce_<op>_<dtype>.hlo.txt      pairwise reduce chunk kernels (18 variants)
  copy_f32.hlo.txt                 collaborative-copy chunk kernel
  train_step_<cfg>.hlo.txt         (params..., tokens) -> (loss, grads...)
  eval_loss_<cfg>.hlo.txt          (params..., tokens) -> (loss,)
  init_params_<cfg>.hlo.txt        (seed,) -> (params...)
  manifest.json                    the Rust runtime's index of all artifacts

Python runs exactly once; afterwards the Rust binary is self-contained.
"""

import argparse
import json
import os
import sys
import time

import jax

# int64 reduce kernels need x64; model code pins float32/int32 explicitly so
# this does not change the model ABI.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .hlo import lower_to_hlo_text  # noqa: E402
from .kernels import reduce as reduce_k  # noqa: E402
from .kernels.wg_copy import make_wg_copy  # noqa: E402
from . import model as model_m  # noqa: E402


def _write(out_dir: str, name: str, text: str, verbose: bool = True) -> str:
    fname = f"{name}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    if verbose:
        print(f"  wrote {fname} ({len(text)} chars)")
    return fname


def emit_reduce(out_dir: str, rows: int, suffix: str) -> dict:
    entries = []
    t0 = time.time()
    for name, fn, args in reduce_k.artifact_entries(rows=rows, suffix=suffix):
        fname = _write(out_dir, name, lower_to_hlo_text(fn, args), verbose=False)
        op, dtype = name.split("_")[1], name.split("_")[2]
        entries.append({"op": op, "dtype": dtype, "file": fname})
    print(f"  {len(entries)} reduce kernels ({rows}x{reduce_k.CHUNK_COLS})"
          f" in {time.time() - t0:.2f}s")
    return {
        "rows": rows,
        "cols": reduce_k.CHUNK_COLS,
        "entries": entries,
    }


def emit_copy(out_dir: str) -> dict:
    rows, cols = reduce_k.CHUNK_ROWS, reduce_k.CHUNK_COLS
    fn = make_wg_copy(rows, cols, "f32")
    spec = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    fname = _write(out_dir, "copy_f32", lower_to_hlo_text(fn, (spec,)))
    return {"rows": rows, "cols": cols, "dtype": "f32", "file": fname}


def emit_model(out_dir: str, cfg_name: str) -> dict:
    cfg = model_m.CONFIGS[cfg_name]
    args = model_m.example_args(cfg)

    t0 = time.time()
    train_file = _write(out_dir, f"train_step_{cfg.name}",
                        lower_to_hlo_text(model_m.make_train_step(cfg), args))
    eval_file = _write(out_dir, f"eval_loss_{cfg.name}",
                       lower_to_hlo_text(model_m.make_eval_loss(cfg), args))

    seed_spec = (jax.ShapeDtypeStruct((), jnp.int32),)
    init_file = _write(
        out_dir, f"init_params_{cfg.name}",
        lower_to_hlo_text(
            lambda seed: tuple(model_m.init_params(seed, cfg)), seed_spec))
    print(f"  model {cfg.name}: lowered in {time.time() - t0:.2f}s "
          f"({model_m.param_count(cfg):,} params)")

    return {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "param_count": model_m.param_count(cfg),
        "params": [
            {"name": n, "shape": list(s)} for n, s in model_m.param_spec(cfg)
        ],
        "train_step": train_file,
        "eval_loss": eval_file,
        "init": init_file,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small",
                    help="comma list from {tiny,small,base100m}")
    ns = ap.parse_args()

    os.makedirs(ns.out_dir, exist_ok=True)
    t0 = time.time()

    print("[aot] reduce kernels")
    manifest = {
        "version": 1,
        "reduce": emit_reduce(ns.out_dir, reduce_k.CHUNK_ROWS, ""),
        # Wide variant: amortizes PJRT launch overhead for bulk folds
        # (gradient allreduce) — see EXPERIMENTS.md §Perf.
        "reduce_wide": emit_reduce(ns.out_dir, reduce_k.WIDE_ROWS, "_wide"),
    }
    print("[aot] copy kernel")
    manifest["copy"] = emit_copy(ns.out_dir)

    manifest["models"] = {}
    for cfg_name in [c for c in ns.models.split(",") if c]:
        if cfg_name not in model_m.CONFIGS:
            print(f"[aot] unknown model config {cfg_name!r}", file=sys.stderr)
            sys.exit(2)
        print(f"[aot] model {cfg_name}")
        manifest["models"][cfg_name] = emit_model(ns.out_dir, cfg_name)

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {ns.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
