"""Layer-1 Pallas kernels for rishmem.

Every kernel here is authored with ``interpret=True`` so that the lowered HLO
contains plain XLA ops executable by any PJRT backend (the Rust coordinator
runs the CPU PJRT client; real-TPU Pallas lowering would emit Mosaic
custom-calls the CPU plugin cannot execute — see DESIGN.md §Hardware-Adaptation).

Kernels:
  reduce     — elementwise pairwise combine (the compute lane of
               ishmem_reduce / ishmemx_reduce_work_group)
  wg_copy    — tiled collaborative copy (the work_group memcpy lanes)
  fused_mlp  — matmul+bias+GELU fused block used by the L2 transformer
"""

from . import ref  # noqa: F401
from .reduce import REDUCE_OPS, REDUCE_DTYPES, make_reduce  # noqa: F401
from .wg_copy import make_wg_copy  # noqa: F401
from .fused_mlp import fused_mlp  # noqa: F401
