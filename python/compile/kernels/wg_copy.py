"""Pallas collaborative-copy kernel — the ``work_group`` memcpy lanes.

Paper §III-F/§III-G.1: the ``ishmemx_put_work_group`` intra-node path is a
multi-threaded vectorized memcpy — every work-item of the SYCL work-group
copies a chunk of the source across the unified address space.  TPU-shaped
adaptation (DESIGN.md §Hardware-Adaptation): the work-items become a Pallas
grid; each grid step moves one (tile_rows, cols) tile through VMEM, which is
the BlockSpec rendering of the HBM↔VMEM schedule the paper wrote with
work-item indexing.

The AOT artifact (``copy_f32``) is used by the Rust runtime for the
"XLA-executed copy" ablation (EXPERIMENTS.md §Ablations); the production put
path is a native memcpy + cost model, because shipping bytes through a PJRT
roundtrip only adds overhead — exactly the kind of cutover decision the
paper's §III-B describes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


@functools.lru_cache(maxsize=None)
def make_wg_copy(rows: int, cols: int, dtype_name: str = "f32",
                 tile_rows: int = 8):
    """Build a tiled identity-copy ``f(src) -> src`` over (rows, cols)."""
    dtype = {"f32": jnp.float32, "i32": jnp.int32, "i64": jnp.int64}[dtype_name]
    out_shape = jax.ShapeDtypeStruct((rows, cols), dtype)

    if rows % tile_rows == 0:
        grid = (rows // tile_rows,)
        spec = pl.BlockSpec((tile_rows, cols), lambda i: (i, 0))
        call = pl.pallas_call(
            _copy_kernel,
            out_shape=out_shape,
            grid=grid,
            in_specs=[spec],
            out_specs=spec,
            interpret=True,
        )
    else:
        call = pl.pallas_call(_copy_kernel, out_shape=out_shape, interpret=True)

    def copy_fn(src):
        return call(jnp.asarray(src, dtype))

    copy_fn.__name__ = f"wg_copy_{dtype_name}_{rows}x{cols}"
    return copy_fn
