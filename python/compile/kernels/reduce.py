"""Pallas reduction kernel — the compute lane of ``ishmem_reduce``.

Paper §III-G.2 ("Reduction"): Intel SHMEM splits a reduction *by address
across threads*, each thread issuing vector loads (one local, one remote),
vector binary ops, and vector stores.  On the TPU-shaped stack the same
insight maps to a Pallas grid over (8, 128)-aligned tiles: each grid step is
the analogue of one work-item's vector lane, BlockSpec expresses the
HBM↔VMEM schedule that SYCL expressed with work-item indexing.

The kernel is a *pairwise* combine ``out = op(a, b)`` over a fixed chunk
shape; the Rust coordinator folds n-way reductions by chaining chunks
(acc = op(acc, contribution_pe)) exactly like the paper's per-PE duplicated
compute.  Fixed shape is an AOT requirement (HLO is static); the runtime
pads the tail chunk.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import REDUCE_REF

#: Chunk layout shared with the Rust runtime (see artifacts/manifest.json):
#: 64 x 128 = 8192 elements per kernel invocation, (8,128)-tileable.
CHUNK_ROWS = 64
CHUNK_COLS = 128
CHUNK_ELEMS = CHUNK_ROWS * CHUNK_COLS

#: Wide variant for bulk folds (gradient allreduce): amortizes the PJRT
#: launch overhead over 8x the elements (§Perf iteration 1 in
#: EXPERIMENTS.md — the per-chunk launch cost dominated at 64x128).
WIDE_ROWS = 512
WIDE_ELEMS = WIDE_ROWS * CHUNK_COLS

#: Tile granularity — the VPU-native (sublane, lane) tile.
TILE_ROWS = 8

REDUCE_OPS = ("sum", "prod", "min", "max", "and", "or", "xor")
#: dtype name -> (jnp dtype, supports bitwise)
REDUCE_DTYPES = {
    "f32": (jnp.float32, False),
    "i32": (jnp.int32, True),
    "i64": (jnp.int64, True),
}
BITWISE_OPS = ("and", "or", "xor")


def op_supported(op: str, dtype_name: str) -> bool:
    """OpenSHMEM defines bitwise reductions only for fixed-point types."""
    if op in BITWISE_OPS:
        return REDUCE_DTYPES[dtype_name][1]
    return True


def _combine_kernel(a_ref, b_ref, o_ref, *, op: str):
    o_ref[...] = REDUCE_REF[op](a_ref[...], b_ref[...])


@functools.partial(
    functools.lru_cache(maxsize=None),
)
def make_reduce(op: str, dtype_name: str, rows: int = CHUNK_ROWS,
                cols: int = CHUNK_COLS, tiled: bool = True):
    """Build ``f(a, b) -> op(a, b)`` over a (rows, cols) chunk.

    ``tiled=True`` runs a grid over (TILE_ROWS, cols) tiles — the
    work-item-lane schedule.  ``tiled=False`` is the whole-block variant used
    by tests for odd shapes.
    """
    if op not in REDUCE_OPS:
        raise ValueError(f"unknown reduce op {op!r}")
    dtype, _ = REDUCE_DTYPES[dtype_name]
    if not op_supported(op, dtype_name):
        raise ValueError(f"op {op!r} undefined for dtype {dtype_name!r}")

    out_shape = jax.ShapeDtypeStruct((rows, cols), dtype)
    kernel = functools.partial(_combine_kernel, op=op)

    if tiled and rows % TILE_ROWS == 0:
        grid = (rows // TILE_ROWS,)
        spec = pl.BlockSpec((TILE_ROWS, cols), lambda i: (i, 0))
        call = pl.pallas_call(
            kernel,
            out_shape=out_shape,
            grid=grid,
            in_specs=[spec, spec],
            out_specs=spec,
            interpret=True,
        )
    else:
        call = pl.pallas_call(kernel, out_shape=out_shape, interpret=True)

    def reduce_fn(a, b):
        a = jnp.asarray(a, dtype)
        b = jnp.asarray(b, dtype)
        return call(a, b)

    reduce_fn.__name__ = f"reduce_{op}_{dtype_name}_{rows}x{cols}"
    return reduce_fn


def artifact_entries(rows: int = CHUNK_ROWS, suffix: str = ""):
    """(name, fn, example_args) for every AOT reduce artifact.

    NOTE (§Perf iteration 2, EXPERIMENTS.md): the AOT artifacts use the
    *whole-block* kernel (``tiled=False``). Under ``interpret=True`` the
    gridded BlockSpec schedule lowers to a while-loop of
    dynamic-update-slices, which costs O(grid × buffer) on the CPU backend;
    the whole-block variant fuses into one elementwise op. On a real TPU
    the tiled variant is the one to compile (VMEM-sized blocks) — both are
    tested against the oracle.
    """
    out = []
    for op in REDUCE_OPS:
        for dtype_name, (dtype, _) in REDUCE_DTYPES.items():
            if not op_supported(op, dtype_name):
                continue
            fn = make_reduce(op, dtype_name, rows=rows, tiled=False)
            spec = jax.ShapeDtypeStruct((rows, CHUNK_COLS), dtype)
            out.append((f"reduce_{op}_{dtype_name}{suffix}", fn, (spec, spec)))
    return out
