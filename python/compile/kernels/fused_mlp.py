"""Pallas fused transformer-MLP kernel: ``gelu(x @ w1 + b1) @ w2 + b2``.

This is the L2 model's compute hot-spot (the MLP is ~2/3 of transformer
FLOPs).  On real TPU hardware this kernel would tile x into (128, d) MXU
panels and keep the (d, 4d) weight panel resident in VMEM; here it is
authored against the same BlockSpec structure but executed with
``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).

Autodiff: ``pallas_call`` has no automatic VJP, so the kernel is wrapped in
``jax.custom_vjp`` with a pure-jnp backward pass.  The forward runs the
Pallas kernel; the backward is standard XLA.  Tests check both value and
gradients against the jnp oracle.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import fused_mlp_ref, gelu_tanh_ref

#: Row-tile granularity of the forward grid (token dimension).
TILE_M = 8


def _gelu(x):
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = _gelu(jnp.dot(x, w1_ref[...]) + b1_ref[...])
    o_ref[...] = jnp.dot(h, w2_ref[...]) + b2_ref[...]


@functools.lru_cache(maxsize=None)
def _make_call(m: int, d: int, h: int, tiled: bool = False):
    """Pallas call for x:(m,d) w1:(d,h) b1:(1,h) w2:(h,d) b2:(1,d).

    ``tiled=False`` by default: under ``interpret=True`` the gridded
    BlockSpec schedule lowers to a while-loop of dynamic-update-slices
    that dominates CPU runtime (EXPERIMENTS.md §Perf iteration 2/4); the
    whole-block variant fuses. ``tiled=True`` keeps the TPU-shaped
    (token-tile × resident-weights) schedule and is value-tested too.
    """
    out_shape = jax.ShapeDtypeStruct((m, d), jnp.float32)
    if tiled and m % TILE_M == 0 and m > TILE_M:
        grid = (m // TILE_M,)
        return pl.pallas_call(
            _mlp_kernel,
            out_shape=out_shape,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TILE_M, d), lambda i: (i, 0)),   # x tile
                pl.BlockSpec((d, h), lambda i: (0, 0)),        # w1 resident
                pl.BlockSpec((1, h), lambda i: (0, 0)),        # b1
                pl.BlockSpec((h, d), lambda i: (0, 0)),        # w2 resident
                pl.BlockSpec((1, d), lambda i: (0, 0)),        # b2
            ],
            out_specs=pl.BlockSpec((TILE_M, d), lambda i: (i, 0)),
            interpret=True,
        )
    return pl.pallas_call(_mlp_kernel, out_shape=out_shape, interpret=True)


def _fwd_impl(x, w1, b1, w2, b2):
    m, d = x.shape
    h = w1.shape[1]
    call = _make_call(m, d, h)
    # Pin f32: the AOT ABI is float32 end-to-end, and a stray f64 operand
    # (x64 mode is on for the int64 reduce kernels) must not leak in.
    x, w1, b1, w2, b2 = (jnp.asarray(v, jnp.float32)
                         for v in (x, w1, b1, w2, b2))
    return call(x, w1, b1.reshape(1, h), w2, b2.reshape(1, d))


@jax.custom_vjp
def fused_mlp(x, w1, b1, w2, b2):
    """Fused MLP block; forward = Pallas kernel, backward = jnp VJP."""
    return _fwd_impl(x, w1, b1, w2, b2)


def _fused_mlp_fwd(x, w1, b1, w2, b2):
    out = _fwd_impl(x, w1, b1, w2, b2)
    return out, (x, w1, b1, w2, b2)


def _fused_mlp_bwd(res, g):
    x, w1, b1, w2, b2 = res
    # Recompute the (cheap) activations; standard rematerialized MLP VJP.
    z = x @ w1 + b1
    a = gelu_tanh_ref(z)
    # dGELU/dz for the tanh approximation.
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=z.dtype))
    t = jnp.tanh(c * (z + 0.044715 * z**3))
    dgelu = 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * c * (1.0 + 3 * 0.044715 * z**2)
    da = g @ w2.T
    dz = da * dgelu
    return (
        dz @ w1.T,            # dx
        x.T @ dz,             # dw1
        dz.sum(axis=0),       # db1
        a.T @ g,              # dw2
        g.sum(axis=0),        # db2
    )


fused_mlp.defvjp(_fused_mlp_fwd, _fused_mlp_bwd)

__all__ = ["fused_mlp", "fused_mlp_ref", "TILE_M"]
