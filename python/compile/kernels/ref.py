"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

These are deliberately written with nothing but ``jax.numpy`` so a bug in the
Pallas authoring (BlockSpec indexing, tiling, accumulation) cannot be
replicated in the oracle.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------- reduce ----

#: OpenSHMEM 1.5 reduction operators (§9.9.4 of the spec; paper §III-G.2).
#: Bitwise ops are only defined for fixed-point types.
REDUCE_REF = {
    "sum": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
}


def reduce_ref(op: str, a, b):
    """Pairwise combine oracle: out[i] = op(a[i], b[i])."""
    return REDUCE_REF[op](a, b)


def reduce_tree_ref(op: str, bufs):
    """Full n-way reduction oracle (what ishmem_reduce computes across PEs)."""
    acc = bufs[0]
    for b in bufs[1:]:
        acc = REDUCE_REF[op](acc, b)
    return acc


# --------------------------------------------------------------- wg_copy ----

def copy_ref(src):
    """Collaborative copy oracle — identity."""
    return jnp.asarray(src)


# ------------------------------------------------------------- fused_mlp ----

def gelu_tanh_ref(x):
    """tanh-approximated GELU (what the kernel implements, exactly)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def fused_mlp_ref(x, w1, b1, w2, b2):
    """Transformer MLP block oracle: gelu(x @ w1 + b1) @ w2 + b2."""
    h = gelu_tanh_ref(x @ w1 + b1)
    return h @ w2 + b2
