"""HLO-text lowering shared by aot.py and the pytest suite.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

All functions are lowered with ``return_tuple=True`` so the Rust runtime can
uniformly ``decompose_tuple`` the single output.
"""

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, example_args) -> str:
    """jit(fn).lower(*args) -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
