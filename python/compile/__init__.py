"""rishmem build-time compile package (L1 Pallas kernels + L2 JAX model).

Nothing in this package is imported at runtime: ``aot.py`` lowers everything
to HLO text once (``make artifacts``) and the Rust coordinator executes the
artifacts through PJRT.
"""
