"""L1 collaborative-copy kernel vs oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.wg_copy import make_wg_copy
from compile.kernels.ref import copy_ref


@pytest.mark.parametrize("dtype_name", ["f32", "i32", "i64"])
def test_chunk_copy(dtype_name):
    rng = np.random.default_rng(0)
    src = rng.integers(-1000, 1000, size=(64, 128))
    if dtype_name == "f32":
        src = src.astype(np.float32)
    fn = make_wg_copy(64, 128, dtype_name)
    np.testing.assert_array_equal(np.asarray(fn(src)), np.asarray(copy_ref(src)))


@settings(max_examples=30, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=16),
    cols=st.sampled_from([128, 256, 384]),
    tile_rows=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tiled_copy_property(tiles, cols, tile_rows, seed):
    """Property: every tile schedule moves every byte exactly once."""
    rows = tiles * tile_rows
    rng = np.random.default_rng(seed)
    src = rng.standard_normal((rows, cols)).astype(np.float32)
    fn = make_wg_copy(rows, cols, "f32", tile_rows=tile_rows)
    np.testing.assert_array_equal(np.asarray(fn(src)), src)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=29),
    cols=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_odd_shape_copy_property(rows, cols, seed):
    rng = np.random.default_rng(seed)
    src = rng.standard_normal((rows, cols)).astype(np.float32)
    fn = make_wg_copy(rows, cols, "f32", tile_rows=64)  # forces untiled path
    np.testing.assert_array_equal(np.asarray(fn(src)), src)
