"""AOT pipeline: artifacts lower, parse as HLO text, manifest is consistent."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as m
from compile.hlo import lower_to_hlo_text
from compile.kernels import reduce as rk
from compile.kernels.wg_copy import make_wg_copy


def test_reduce_artifact_lowers_to_hlo(tmp_path):
    fn = rk.make_reduce("sum", "f32")
    spec = jax.ShapeDtypeStruct((rk.CHUNK_ROWS, rk.CHUNK_COLS), jnp.float32)
    text = lower_to_hlo_text(fn, (spec, spec))
    assert text.startswith("HloModule")
    # interpret=True must not leave Mosaic custom-calls behind.
    assert "custom-call" not in text or "Mosaic" not in text


def test_copy_artifact_lowers_to_hlo():
    fn = make_wg_copy(rk.CHUNK_ROWS, rk.CHUNK_COLS, "f32")
    spec = jax.ShapeDtypeStruct((rk.CHUNK_ROWS, rk.CHUNK_COLS), jnp.float32)
    text = lower_to_hlo_text(fn, (spec,))
    assert text.startswith("HloModule")


def test_model_artifacts_lower(tmp_path):
    cfg = m.CONFIGS["tiny"]
    entry = aot.emit_model(str(tmp_path), "tiny")
    for key in ("train_step", "eval_loss", "init"):
        path = tmp_path / entry[key]
        assert path.exists()
        head = path.read_text()[:200]
        assert head.startswith("HloModule"), head
    assert entry["param_count"] == m.param_count(cfg)
    assert len(entry["params"]) == len(m.param_spec(cfg))


def test_full_emit_manifest_consistent(tmp_path, monkeypatch):
    """Run the real CLI entry end-to-end for the tiny model."""
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(tmp_path), "--models", "tiny"])
    aot.main()

    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    red = manifest["reduce"]
    assert red["rows"] * red["cols"] == rk.CHUNK_ELEMS
    # 4 ops x 3 dtypes + 3 bitwise x 2 int dtypes = 18 artifacts
    assert len(red["entries"]) == 18
    for e in red["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert rk.op_supported(e["op"], e["dtype"])
    assert (tmp_path / manifest["copy"]["file"]).exists()
    tiny = manifest["models"]["tiny"]
    assert (tmp_path / tiny["train_step"]).exists()
    assert [p["name"] for p in tiny["params"]] == \
        [n for n, _ in m.param_spec(m.CONFIGS["tiny"])]
