"""L1 reduce kernel vs pure-jnp oracle (hypothesis sweeps shapes/dtypes/ops)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import reduce as rk
from compile.kernels.ref import reduce_ref, reduce_tree_ref

ALL_CASES = [
    (op, dt)
    for op in rk.REDUCE_OPS
    for dt in rk.REDUCE_DTYPES
    if rk.op_supported(op, dt)
]


def _rand(shape, dtype_name, rng):
    if dtype_name == "f32":
        # prod overflows explode with wide ranges; keep values near 1.
        return (0.5 + rng.random(shape)).astype(np.float32)
    dt = np.int32 if dtype_name == "i32" else np.int64
    return rng.integers(-100, 100, size=shape).astype(dt)


@pytest.mark.parametrize("op,dtype_name", ALL_CASES)
def test_chunk_matches_ref(op, dtype_name):
    """Default AOT chunk shape, tiled grid path."""
    rng = np.random.default_rng(42)
    a = _rand((rk.CHUNK_ROWS, rk.CHUNK_COLS), dtype_name, rng)
    b = _rand((rk.CHUNK_ROWS, rk.CHUNK_COLS), dtype_name, rng)
    fn = rk.make_reduce(op, dtype_name)
    got = np.asarray(fn(a, b))
    want = np.asarray(reduce_ref(op, jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    case=st.sampled_from(ALL_CASES),
    rows_tiles=st.integers(min_value=1, max_value=8),
    cols=st.sampled_from([128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tiled_shapes_property(case, rows_tiles, cols, seed):
    """Property: tiled kernel == oracle for every (8k, 128m) chunk shape."""
    op, dtype_name = case
    rows = rk.TILE_ROWS * rows_tiles
    rng = np.random.default_rng(seed)
    a = _rand((rows, cols), dtype_name, rng)
    b = _rand((rows, cols), dtype_name, rng)
    fn = rk.make_reduce(op, dtype_name, rows=rows, cols=cols)
    got = np.asarray(fn(a, b))
    want = np.asarray(reduce_ref(op, jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    case=st.sampled_from(ALL_CASES),
    rows=st.integers(min_value=1, max_value=23),
    cols=st.integers(min_value=1, max_value=50),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_whole_block_odd_shapes_property(case, rows, cols, seed):
    """Property: untiled fallback handles arbitrary (non-tile) shapes."""
    op, dtype_name = case
    rng = np.random.default_rng(seed)
    a = _rand((rows, cols), dtype_name, rng)
    b = _rand((rows, cols), dtype_name, rng)
    fn = rk.make_reduce(op, dtype_name, rows=rows, cols=cols, tiled=False)
    got = np.asarray(fn(a, b))
    want = np.asarray(reduce_ref(op, jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("op", ["sum", "min", "max", "xor"])
def test_nway_fold_matches_tree_ref(op):
    """Chaining the pairwise kernel reproduces the n-way reduction the Rust
    coordinator performs across PEs (paper §III-G.2)."""
    dtype_name = "i64" if op == "xor" else "f32"
    rng = np.random.default_rng(7)
    bufs = [_rand((rk.CHUNK_ROWS, rk.CHUNK_COLS), dtype_name, rng)
            for _ in range(6)]
    fn = rk.make_reduce(op, dtype_name)
    acc = bufs[0]
    for b in bufs[1:]:
        acc = np.asarray(fn(acc, b))
    want = np.asarray(reduce_tree_ref(op, [jnp.asarray(b) for b in bufs]))
    np.testing.assert_allclose(acc, want, rtol=1e-5)


def test_bitwise_rejected_for_float():
    with pytest.raises(ValueError):
        rk.make_reduce("xor", "f32")


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        rk.make_reduce("avg", "f32")


@pytest.mark.parametrize("op,dtype_name", ALL_CASES)
def test_identity_values(op, dtype_name):
    """op(x, identity) == x — the identity element the Rust runtime uses to
    pad tail chunks must be absorbed exactly."""
    ident = {
        "sum": 0, "prod": 1, "min": None, "max": None,
        "and": -1, "or": 0, "xor": 0,
    }[op]
    if ident is None:
        # min/max identities are dtype extremes.
        if dtype_name == "f32":
            ident = np.inf if op == "min" else -np.inf
        else:
            info = np.iinfo(np.int32 if dtype_name == "i32" else np.int64)
            ident = info.max if op == "min" else info.min
    rng = np.random.default_rng(3)
    a = _rand((rk.CHUNK_ROWS, rk.CHUNK_COLS), dtype_name, rng)
    b = np.full_like(a, ident)
    fn = rk.make_reduce(op, dtype_name)
    np.testing.assert_allclose(np.asarray(fn(a, b)), a, rtol=1e-6)
