import os
import sys

import jax

# Kernel tests sweep int64 — enable x64 before anything traces.
jax.config.update("jax_enable_x64", True)

# Tests may be launched from the repo root or from python/; make the
# `compile` package importable either way.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
