"""L1 fused MLP kernel: forward vs oracle, custom-VJP grads vs autodiff oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_mlp import fused_mlp
from compile.kernels.ref import fused_mlp_ref


def _case(m, d, h, seed=0):
    rng = np.random.default_rng(seed)
    # NB: keep every scale as a final .astype — np.float64 scalars (np.sqrt)
    # are "strong" under NumPy-2 promotion and would silently upcast to f64.
    x = (rng.standard_normal((m, d)) * 0.5).astype(np.float32)
    w1 = (rng.standard_normal((d, h)) / np.sqrt(d)).astype(np.float32)
    b1 = (rng.standard_normal(h) * 0.01).astype(np.float32)
    w2 = (rng.standard_normal((h, d)) / np.sqrt(h)).astype(np.float32)
    b2 = (rng.standard_normal(d) * 0.01).astype(np.float32)
    return tuple(jnp.asarray(v) for v in (x, w1, b1, w2, b2))


@pytest.mark.parametrize("m,d,h", [(8, 16, 64), (16, 32, 128), (5, 8, 32),
                                   (64, 32, 128)])
def test_forward_matches_ref(m, d, h):
    args = _case(m, d, h)
    got = fused_mlp(*args)
    want = fused_mlp_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 8, 24, 40]),
    d=st.sampled_from([8, 16, 32]),
    h_mult=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_forward_property(m, d, h_mult, seed):
    args = _case(m, d, d * h_mult, seed)
    np.testing.assert_allclose(
        np.asarray(fused_mlp(*args)), np.asarray(fused_mlp_ref(*args)),
        rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,d,h", [(8, 16, 64), (5, 8, 32)])
def test_gradients_match_ref(m, d, h):
    """custom_vjp backward == jax.grad through the pure-jnp oracle."""
    args = _case(m, d, h, seed=3)

    def loss_kernel(*a):
        return (fused_mlp(*a) ** 2).sum()

    def loss_ref(*a):
        return (fused_mlp_ref(*a) ** 2).sum()

    g_kernel = jax.grad(loss_kernel, argnums=tuple(range(5)))(*args)
    g_ref = jax.grad(loss_ref, argnums=tuple(range(5)))(*args)
    for gk, gr, name in zip(g_kernel, g_ref, ["x", "w1", "b1", "w2", "b2"]):
        np.testing.assert_allclose(
            np.asarray(gk), np.asarray(gr), rtol=5e-4, atol=5e-5,
            err_msg=f"grad mismatch for {name}")


def test_jit_compatible():
    args = _case(8, 16, 64)
    got = jax.jit(fused_mlp)(*args)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(fused_mlp_ref(*args)),
                               rtol=2e-5, atol=2e-5)


def test_tiled_schedule_matches_whole_block():
    """The TPU-shaped tiled schedule computes the same values as the
    whole-block variant the CPU artifacts use."""
    from compile.kernels.fused_mlp import _make_call
    m, d, h = 64, 16, 32
    args = _case(m, d, h, seed=11)
    x, w1, b1, w2, b2 = args
    tiled = _make_call(m, d, h, tiled=True)(
        x, w1, b1.reshape(1, h), w2, b2.reshape(1, d))
    whole = _make_call(m, d, h, tiled=False)(
        x, w1, b1.reshape(1, h), w2, b2.reshape(1, d))
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(whole),
                               rtol=1e-6, atol=1e-6)
