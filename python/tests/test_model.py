"""L2 transformer: shapes, determinism, loss behaviour, train-step contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m


CFG = m.CONFIGS["tiny"]


def _tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)), jnp.int32)


def test_param_spec_shapes_cover_all_layers():
    spec = m.param_spec(CFG)
    names = [n for n, _ in spec]
    assert names[0] == "tok_emb" and names[1] == "pos_emb"
    assert names[-2:] == ["lnf_scale", "lnf_bias"]
    assert sum(1 for n in names if n.startswith("layer0.")) == 12
    assert len(set(names)) == len(names)


def test_param_count_consistent():
    params = m.init_params(0, CFG)
    assert sum(int(np.prod(p.shape)) for p in params) == m.param_count(CFG)
    for p, (_, shape) in zip(params, m.param_spec(CFG)):
        assert p.shape == shape
        assert p.dtype == jnp.float32


def test_base100m_is_paper_scale():
    """The base100m config exists and really is ~100M parameters."""
    n = m.param_count(m.CONFIGS["base100m"])
    assert 80_000_000 <= n <= 150_000_000, n


def test_init_deterministic():
    a = m.init_params(7, CFG)
    b = m.init_params(7, CFG)
    c = m.init_params(8, CFG)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(
        not np.array_equal(np.asarray(x), np.asarray(z)) for x, z in zip(a, c))


def test_forward_shape_and_finite():
    params = m.init_params(0, CFG)
    logits = m.forward(params, _tokens(CFG), CFG)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_loss_near_uniform_at_init():
    """With 0.02-std embeddings, initial loss ≈ ln(vocab)."""
    params = m.init_params(0, CFG)
    loss = m.loss_fn(params, _tokens(CFG), CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_causality():
    """Changing future tokens must not change past logits."""
    params = m.init_params(0, CFG)
    toks = np.asarray(_tokens(CFG))
    logits_a = np.asarray(m.forward(params, jnp.asarray(toks), CFG))
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
    logits_b = np.asarray(m.forward(params, jnp.asarray(toks2), CFG))
    np.testing.assert_allclose(logits_a[:, :-1, :], logits_b[:, :-1, :],
                               rtol=1e-5, atol=1e-6)


def test_train_step_contract_and_loss_decreases():
    """train_step returns (loss, grads...) matching param shapes; a few SGD
    steps on a fixed batch reduce the loss (overfit signal)."""
    step = jax.jit(m.make_train_step(CFG))
    params = m.init_params(0, CFG)
    toks = _tokens(CFG)

    out = step(*params, toks)
    assert len(out) == 1 + len(params)
    loss0 = float(out[0])
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape

    lr = 0.5
    for _ in range(10):
        out = step(*params, toks)
        params = [p - lr * g for p, g in zip(params, out[1:])]
    loss1 = float(out[0])
    assert np.isfinite(loss1)
    assert loss1 < loss0 - 0.1, (loss0, loss1)


def test_eval_loss_matches_loss_fn():
    ev = jax.jit(m.make_eval_loss(CFG))
    params = m.init_params(0, CFG)
    toks = _tokens(CFG)
    np.testing.assert_allclose(float(ev(*params, toks)[0]),
                               float(m.loss_fn(params, toks, CFG)), rtol=1e-5)
