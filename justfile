# Developer entry points (`just --list`). The make-style targets mirror
# the ROADMAP's tier-1 verify command.

# Tier-1 verify: build + full test suite. `integration_runtime` and
# `integration_train` skip gracefully unless `make artifacts` has been
# run; everything else (including `integration_chain`) runs on the
# simulated machine alone.
verify:
    cargo build --workspace --release
    cargo test -q

# Paper-figure benches (plain binaries, no libtest harness).
bench:
    cargo bench --bench fig5_cutover
    cargo bench --bench fig_batch
    cargo bench --bench fig_stripe
    cargo bench --bench fig_rail
    cargo bench --bench fig_coll_scale
    cargo bench --bench fig_calib
    cargo bench --bench fig_fault
    cargo bench --bench fig_retry
    cargo bench --bench fig_chain
    cargo bench --bench fig3_rma
    cargo bench --bench hot_path

# CI smoke: the cutover + batched-submission + striped-pipeline +
# rail-striping + collective-scaling + calibration + fault-injection +
# transfer-reliability + triggered-chain + hot-path benches on tiny
# sweeps (RISHMEM_SMOKE shrinks the size/nelem grids, the calibration
# round count, and the plans/sec iteration counts), so the figure
# benches and their embedded assertions (including the plan-cache
# speedup, zero-drift, and single-doorbell-per-chain checks) can't
# bit-rot.
bench-smoke:
    RISHMEM_SMOKE=1 cargo bench --bench fig5_cutover
    RISHMEM_SMOKE=1 cargo bench --bench fig_batch
    RISHMEM_SMOKE=1 cargo bench --bench fig_stripe
    RISHMEM_SMOKE=1 cargo bench --bench fig_rail
    RISHMEM_SMOKE=1 cargo bench --bench fig_coll_scale
    RISHMEM_SMOKE=1 cargo bench --bench fig_calib
    RISHMEM_SMOKE=1 cargo bench --bench fig_fault
    RISHMEM_SMOKE=1 cargo bench --bench fig_retry
    RISHMEM_SMOKE=1 cargo bench --bench fig_chain
    RISHMEM_SMOKE=1 cargo bench --bench hot_path

# Formatting gate (no writes).
fmt-check:
    cargo fmt --all -- --check

# Regenerate every paper figure via the CLI.
figures:
    cargo run --release -- figure all
