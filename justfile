# Developer entry points (`just --list`). The make-style targets mirror
# the ROADMAP's tier-1 verify command.

# Tier-1 verify: build + full test suite.
verify:
    cargo build --release
    cargo test -q

# Paper-figure benches (plain binaries, no libtest harness).
bench:
    cargo bench --bench fig5_cutover
    cargo bench --bench fig3_rma
    cargo bench --bench hot_path

# Formatting gate (no writes).
fmt-check:
    cargo fmt --all -- --check

# Regenerate every paper figure via the CLI.
figures:
    cargo run --release -- figure all
